//! Master scheduler (paper: rank 0) — the only process holding the
//! complete algorithm description.  Assigns jobs to sub-schedulers with
//! locality-aware placement, processes runtime job injections, orchestrates
//! fault recovery, releases dead results, and collects the final segment's
//! outputs.
//!
//! Two control planes share this file (DESIGN.md §7):
//!
//! * **Barrier** (`Master::drive_barrier`) — the paper's literal model:
//!   segments execute in order and segment *k+1* starts only when every job
//!   of segment *k* (including injected ones) has terminated.
//! * **Dataflow** (`Master::drive_dataflow`, the default) — a
//!   dependency-DAG executor built on [`super::graph::JobGraph`]: a job is
//!   assigned the moment every result it references is available, across
//!   segment boundaries.  Segment indices survive as the injection
//!   namespace and the [`ReleasePolicy::Lagged`] reference frame.
//!
//! The master stores **no job data** (paper §3.1): results move between
//! sub-schedulers and workers; the master tracks only *where* they are
//! ([`SourceLoc`]) and *whether* they are still needed.

use std::collections::{BTreeMap, HashMap, HashSet, VecDeque};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::comm::{Comm, CommCalibration, Envelope, Match, Rank, TransferEstimate};
use crate::config::ExecutionMode;
use crate::cost::CostTable;
use crate::data::FunctionData;
use crate::error::{Error, Result};
use crate::fault::FailureReport;
use crate::job::{Algorithm, ChunkRange, Injection, JobId, JobSpec};
use crate::metrics::MetricsCollector;

use super::dynamic::resolve_injections;
use super::graph::{JobGraph, NodeState};
use super::placement::{apply_memory_pressure, bulk_assign_order, choose_scheduler_policy};
use super::{log_unroutable, Coalescer, CtrlBatchCfg, FwMsg, HeartbeatDetector, SourceLoc};

/// When stored results are freed (see DESIGN.md §6 discussion).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReleasePolicy {
    /// Free everything at shutdown (default — always safe under dynamic
    /// job injection, memory cost is bounded by the run's total output).
    AtShutdown,
    /// Free a result `lag` segments after its last known reference.
    /// Safe when injections never reach further back than `lag` segments
    /// (the Jacobi cycle needs `lag >= 2`).
    ///
    /// Under barrier execution the horizon is the closing segment index;
    /// under dataflow it is the **frontier** (oldest segment with live
    /// jobs), and a result is additionally held until its graph out-edges
    /// have drained — dependency-count release instead of segment-close
    /// release (DESIGN.md §6).
    Lagged {
        /// Segments a result survives past its last known reference.
        lag: usize,
    },
}

/// Master-side run parameters.
pub struct MasterConfig {
    /// Sub-scheduler ranks the master assigns to.
    pub subs: Vec<Rank>,
    /// When stored results are freed.
    pub release: ReleasePolicy,
    /// Barrier vs dataflow control plane.
    pub mode: ExecutionMode,
    /// Speculative input prefetch (dataflow mode, DESIGN.md §7): hint the
    /// probable target of a `Waiting` job with all inputs but one
    /// materialised to pull the remote ones early.
    pub prefetch: bool,
    /// Feedback-driven cost model (DESIGN.md §9): fold observed job
    /// execution times into a per-kind EWMA and break placement ties by
    /// estimated outstanding cost instead of queue length.
    pub cost_model: bool,
    /// EWMA smoothing factor of the cost table (`(0, 1]`).
    pub cost_ewma_alpha: f64,
    /// Comm-aware placement (DESIGN.md §10, knob `comm_aware_placement`):
    /// price candidate targets by estimated compute backlog **plus**
    /// modelled transfer time, with size-normalised (µs/byte) job
    /// estimates.  Off reproduces the PR 4 byte-affinity placement
    /// bit-for-bit.
    pub comm_aware: bool,
    /// The world's per-peer transfer calibration — the α/β model refined
    /// by observed transfer times (read-only here; the transport feeds it).
    pub comm: Arc<CommCalibration>,
    /// Control-plane coalescing + amortised passes (DESIGN.md §12, knob
    /// `ctrl_batching`): buffer outgoing control messages into `Batch`
    /// frames, drain the whole mailbox per dataflow scheduling pass, and
    /// place the ready frontier in one cost-sorted bulk pass.  Disabled =
    /// the PR 5 one-message-one-pass control plane, bit for bit.
    pub ctrl_batch: CtrlBatchCfg,
    /// Heartbeat failure detection (DESIGN.md §14, knob `heartbeats`):
    /// beat every sub-scheduler each interval and declare a rank lost
    /// after `heartbeat_miss_limit` silent intervals.  Off = the blocking
    /// PR 7 event loop, bit for bit.
    pub heartbeats: bool,
    /// Beat interval (also the hardened event loop's idle poll period).
    pub heartbeat_interval: Duration,
    /// Consecutive silent intervals before a sub-scheduler is declared
    /// lost and recovered.
    pub heartbeat_miss_limit: u32,
    /// Deadline-based straggler re-execution (DESIGN.md §14, knob
    /// `straggler_deadlines`): speculatively re-place in-flight jobs that
    /// outlive their §9 cost-model deadline; first completion wins.
    pub stragglers: bool,
    /// Deadline multiple of the cost-model estimate.
    pub straggler_factor: f64,
    /// Deadline floor, µs, for kinds the cost model knows nothing about.
    pub straggler_cold_us: u64,
    /// Sub-scheduler losses tolerated before the run degrades gracefully
    /// with [`Error::Degraded`] (DESIGN.md §14).
    pub max_rank_losses: usize,
    /// Extra slack, µs, added per retry to a job's next replica deadline —
    /// the backoff of the speculative re-placement loop.
    pub job_retry_backoff_us: u64,
    /// Per-rank store byte budget (DESIGN.md §16, knob
    /// `memory_budget_bytes`): with a budget in force the master tracks
    /// stored bytes per sub and penalises placement onto near-budget
    /// ranks.  0 = unbounded — placement inputs stay bit-for-bit PR 9.
    pub memory_budget_bytes: u64,
}

/// Drive one algorithm to completion. Returns the results of the final
/// segment's jobs (fetched from their owning sub-schedulers).
pub fn run_master(
    comm: &mut Comm<FwMsg>,
    algo: Algorithm,
    cfg: MasterConfig,
    metrics: &MetricsCollector,
) -> Result<BTreeMap<JobId, FunctionData>> {
    Master::new(comm, cfg, metrics).run(algo)
}

struct Master<'a> {
    comm: &'a mut Comm<FwMsg>,
    cfg: MasterConfig,
    metrics: &'a MetricsCollector,

    segments: Vec<Vec<JobSpec>>,
    specs: HashMap<JobId, JobSpec>,
    /// Segment each job was declared in (release horizon anchor; O(1)
    /// final-segment membership).
    produced_in: HashMap<JobId, usize>,
    owners: HashMap<JobId, SourceLoc>,
    result_bytes: HashMap<JobId, u64>,
    available: HashSet<JobId>,
    last_use: HashMap<JobId, usize>,
    load: HashMap<Rank, usize>,
    /// Per-job-kind EWMA of observed execution time (DESIGN.md §9; only
    /// fed while `cfg.cost_model` is on).
    costs: CostTable,
    /// Estimated execution microseconds charged per in-flight job at
    /// assignment (refunded when the job leaves the in-flight set, so the
    /// books stay balanced even when the estimate has drifted since).
    est_charged: HashMap<JobId, u64>,
    /// Estimated outstanding execution microseconds per sub-scheduler —
    /// the cost model's replacement for queue length in placement
    /// tie-breaks.
    est_load: HashMap<Rank, u64>,
    /// Stored result bytes the master believes each sub holds (charged on
    /// completion, credited on release or loss) — the memory-pressure
    /// input of §16 placement.  Only maintained to steer placement; the
    /// sub's own ledger is authoritative for eviction.
    stored_bytes: HashMap<Rank, u64>,
    pending: HashSet<JobId>,
    /// Abort counts per job — a cycle-breaker: a job repeatedly aborted by
    /// its scheduler indicates an unrecoverable condition, not a fault.
    /// Cleared on completion so a long fault-heavy run cannot trip the
    /// limit across independent recovery episodes.
    abort_counts: HashMap<JobId, usize>,
    next_id: u32,

    // ----- barrier-mode state
    /// Jobs needing (re-)execution whose inputs may not be available yet.
    recovery: VecDeque<JobId>,
    seg_idx: usize,

    // ----- dataflow-mode state
    graph: JobGraph,
    /// Not-yet-done jobs per segment (metrics: when a segment drains, its
    /// entry is closed).
    seg_outstanding: Vec<usize>,
    seg_closed: Vec<bool>,
    /// Results whose release eligibility may have changed since the last
    /// release pass (their own completion, a consumer draining, or the
    /// final segment moving) — the incremental replacement for scanning
    /// every available result per completion.
    release_candidates: Vec<JobId>,
    /// Results blocked only on the lag horizon, keyed by the frontier
    /// value that unblocks them (`last_use + lag`).
    lag_parked: BTreeMap<usize, Vec<JobId>>,
    /// Membership set for `lag_parked` (dedupe).
    parked: HashSet<JobId>,
    /// Outstanding prefetch hints: hinted job → (predicted target, hinted
    /// source jobs).  Resolved at assignment — a mispredicted target gets
    /// cancel hints (`ReleaseResult`) for the copies it pulled — or on
    /// node re-entry, which also re-opens the hint window for the job.
    prefetch_hints: HashMap<JobId, (Rank, Vec<JobId>)>,

    // ----- control-plane batching (DESIGN.md §12)
    /// Per-destination outgoing-message coalescer.
    coal: Coalescer,
    /// Event-loop microseconds spent handling messages + scheduling.
    busy_us: u64,
    /// Event-loop microseconds spent blocked waiting for mail.
    idle_us: u64,

    // ----- failure hardening (DESIGN.md §14)
    /// Liveness detector over the sub-scheduler ranks (`heartbeats` on).
    hb: Option<HeartbeatDetector>,
    /// Per-job replica tracking for deadline-based straggler re-execution
    /// (`straggler_deadlines` on; entries live exactly as long as the job
    /// is in `pending`).
    inflight: HashMap<JobId, Inflight>,
    /// Sub-schedulers declared lost so far (the degradation budget).
    lost_ranks: Vec<Rank>,
}

/// A job aborted more often than this fails the run.
const MAX_ABORTS_PER_JOB: usize = 8;

/// Replicas one job may be dispatched to before the run degrades — the
/// per-job half of the graceful-degradation budget (DESIGN.md §14; the
/// per-run half is `max_rank_losses`).
const MAX_SPECULATIVE_TRIES: u32 = 4;

/// Idle poll period of the hardened event loop when straggler deadlines
/// are on but heartbeats are off (with heartbeats on the beat interval
/// paces the loop instead).
const STRAGGLER_POLL: Duration = Duration::from_millis(50);

/// In-flight replica bookkeeping of one job (DESIGN.md §14).
struct Inflight {
    /// `(rank, estimated µs charged there)` per replica, dispatch order —
    /// the first entry is the original assignment.
    targets: Vec<(Rank, u64)>,
    /// When the newest replica was dispatched.
    since: Instant,
    /// Deadline of the newest replica, µs past `since`.
    deadline_us: u64,
    /// Replicas dispatched so far.
    tries: u32,
}

/// Distinct producer jobs referenced by a spec (dependency edges for the
/// critical-path metrics and the release-candidate offers).
fn distinct_inputs(spec: &JobSpec) -> Vec<JobId> {
    let mut ps: Vec<JobId> = spec.inputs.iter().map(|r| r.job).collect();
    ps.sort();
    ps.dedup();
    ps
}

impl<'a> Master<'a> {
    fn new(comm: &'a mut Comm<FwMsg>, cfg: MasterConfig, metrics: &'a MetricsCollector) -> Self {
        let costs = CostTable::new(cfg.cost_ewma_alpha);
        let coal = Coalescer::new(cfg.ctrl_batch);
        let hb = if cfg.heartbeats {
            Some(HeartbeatDetector::new(
                &cfg.subs,
                cfg.heartbeat_interval,
                cfg.heartbeat_miss_limit,
                Instant::now(),
            ))
        } else {
            None
        };
        Master {
            hb,
            inflight: HashMap::new(),
            lost_ranks: Vec::new(),
            coal,
            busy_us: 0,
            idle_us: 0,
            comm,
            cfg,
            metrics,
            segments: Vec::new(),
            specs: HashMap::new(),
            produced_in: HashMap::new(),
            owners: HashMap::new(),
            result_bytes: HashMap::new(),
            available: HashSet::new(),
            last_use: HashMap::new(),
            load: HashMap::new(),
            costs,
            est_charged: HashMap::new(),
            est_load: HashMap::new(),
            stored_bytes: HashMap::new(),
            pending: HashSet::new(),
            abort_counts: HashMap::new(),
            next_id: 0,
            recovery: VecDeque::new(),
            seg_idx: 0,
            graph: JobGraph::new(),
            seg_outstanding: Vec::new(),
            seg_closed: Vec::new(),
            release_candidates: Vec::new(),
            lag_parked: BTreeMap::new(),
            parked: HashSet::new(),
            prefetch_hints: HashMap::new(),
        }
    }

    fn run(mut self, algo: Algorithm) -> Result<BTreeMap<JobId, FunctionData>> {
        algo.validate()?;
        self.next_id = algo.max_job_id() + 1;
        self.segments = algo.segments.into_iter().map(|s| s.jobs).collect();
        for (idx, seg) in self.segments.iter().enumerate() {
            for j in seg {
                self.specs.insert(j.id, j.clone());
                self.produced_in.insert(j.id, idx);
                self.metrics.job_dependencies(j.id, &distinct_inputs(j));
            }
        }
        self.recompute_last_use();

        let outcome = match self.cfg.mode {
            ExecutionMode::Barrier => self.drive_barrier(),
            ExecutionMode::Dataflow => self.drive_dataflow(),
        };
        self.metrics.master_loop(self.busy_us, self.idle_us);
        match outcome {
            Ok(()) => {
                let finals = self.collect_final_results();
                self.broadcast_shutdown();
                finals
            }
            Err(e) => {
                self.broadcast_shutdown();
                Err(e)
            }
        }
    }

    fn recompute_last_use(&mut self) {
        for (idx, seg) in self.segments.iter().enumerate() {
            for job in seg {
                for r in &job.inputs {
                    let e = self.last_use.entry(r.job).or_insert(idx);
                    *e = (*e).max(idx);
                }
            }
        }
    }

    // ================================================== barrier execution

    fn drive_barrier(&mut self) -> Result<()> {
        while self.seg_idx < self.segments.len() {
            let jobs: Vec<JobId> =
                self.segments[self.seg_idx].iter().map(|j| j.id).collect();
            self.metrics.segment_opened(jobs.len());
            let mut to_assign: VecDeque<JobId> = jobs.into();

            while !to_assign.is_empty() || !self.pending.is_empty() {
                while let Some(job) = to_assign.pop_front() {
                    self.assign_or_defer(job);
                }
                if self.pending.is_empty() && self.recovery.is_empty() {
                    break;
                }
                if self.pending.is_empty() && !self.recovery.is_empty() {
                    // Everything waits on recovery jobs whose deps never
                    // became available — unrecoverable.
                    let stuck = self.recovery.front().copied().expect("nonempty");
                    let missing: Vec<String> = self
                        .specs
                        .get(&stuck)
                        .map(|s| {
                            s.inputs
                                .iter()
                                .filter(|r| !self.available.contains(&r.job))
                                .map(|r| r.to_string())
                                .collect()
                        })
                        .unwrap_or_default();
                    return Err(Error::JobFailed {
                        job: stuck,
                        msg: format!(
                            "recovery stuck in segment {}: missing inputs {:?}, {} more jobs queued",
                            self.seg_idx,
                            missing,
                            self.recovery.len() - 1
                        ),
                    });
                }
                // Pass boundary: ship buffered Assigns before blocking
                // (DESIGN.md §12); a no-op with coalescing off.
                self.coal.flush_all(self.comm, self.metrics);
                let env = self.recv_event()?;
                let from = env.src;
                self.handle_barrier(from, env.into_user(), &mut to_assign)?;
                self.hardening_pass()?;
            }

            self.metrics.segment_closed();
            self.apply_barrier_release();
            self.seg_idx += 1;
        }
        Ok(())
    }

    fn handle_barrier(
        &mut self,
        from: Rank,
        msg: FwMsg,
        to_assign: &mut VecDeque<JobId>,
    ) -> Result<()> {
        match msg {
            FwMsg::JobDone { job, kept_on, chunks, injections, output_bytes, exec_us } => {
                if self.tolerate_duplicate_done(from, job) {
                    return Ok(());
                }
                self.settle_replicas(from, job);
                self.observe_cost(job, exec_us);
                // Process injections before completing the job: a batch
                // may target the *current* segment.
                if !injections.is_empty() {
                    let count: usize = injections.iter().map(|i| i.jobs.len()).sum();
                    let resolved = resolve_injections(
                        injections,
                        self.seg_idx,
                        &mut self.next_id,
                        |id| self.specs.contains_key(&id),
                    )?;
                    self.metrics.jobs_injected(count);
                    for batch in resolved {
                        while self.segments.len() <= batch.segment_index {
                            self.segments.push(Vec::new());
                        }
                        for spec in batch.jobs {
                            self.specs.insert(spec.id, spec.clone());
                            self.produced_in.insert(spec.id, batch.segment_index);
                            self.metrics
                                .job_dependencies(spec.id, &distinct_inputs(&spec));
                            for r in &spec.inputs {
                                let e = self
                                    .last_use
                                    .entry(r.job)
                                    .or_insert(batch.segment_index);
                                *e = (*e).max(batch.segment_index);
                            }
                            if batch.segment_index == self.seg_idx {
                                to_assign.push_back(spec.id);
                            }
                            self.segments[batch.segment_index].push(spec);
                        }
                    }
                }
                self.complete_job(from, job, kept_on, output_bytes);
                let _ = chunks;
                self.try_recovery();
                Ok(())
            }
            FwMsg::JobError { job, msg } => Err(Error::JobFailed { job, msg }),
            FwMsg::JobAborted { job, missing } => {
                if self.stale_replica_abort(job) {
                    return Ok(());
                }
                self.count_abort(job, missing)?;
                self.forget_pending(job);
                self.queue_recovery(job);
                if !self.available.contains(&missing) && !self.pending.contains(&missing)
                {
                    self.queue_recovery(missing);
                }
                self.try_recovery();
                Ok(())
            }
            // Tolerated post-recovery: a sub may legitimately re-report a
            // loss the heartbeat detector (or an earlier report) already
            // recovered — every step below is idempotent (DESIGN.md §14).
            FwMsg::WorkerLostReport { lost, running, .. } => {
                for job in lost {
                    if self.available.remove(&job) {
                        self.credit_stored_bytes(job);
                    }
                    if let Some(loc) = self.owners.get_mut(&job) {
                        loc.kept_on = None;
                    }
                    if self.still_needed_barrier(job) {
                        self.metrics.job_recomputed();
                        self.queue_recovery(job);
                    }
                }
                for job in running {
                    if self.forget_pending(job) {
                        self.metrics.job_recomputed();
                        self.queue_recovery(job);
                    }
                }
                self.try_recovery();
                Ok(())
            }
            FwMsg::Batch(msgs) => {
                // Coalesced frame from a sub (DESIGN.md §12): members
                // apply in arrival order.
                for m in msgs {
                    self.handle_barrier(from, m, to_assign)?;
                }
                Ok(())
            }
            // Liveness reply (DESIGN.md §14): the receive path already
            // credited the sender; nothing else to do — including late
            // acks from a rank recovery already wrote off.
            FwMsg::HeartbeatAck => Ok(()),
            // hypar-lint: L1 wildcard-ok — subs route only the
            // completion-shaped traffic matched above to the master
            // mid-run.  Late fetch replies racing a collection pass are
            // tolerated silently; anything else is a protocol bug and the
            // drop is loud in debug builds (DESIGN.md §13).
            FwMsg::ResultData { .. } | FwMsg::ResultUnavailable { .. } => Ok(()),
            other => {
                log_unroutable("master/barrier", &other);
                Ok(())
            }
        }
    }

    fn still_needed_barrier(&self, job: JobId) -> bool {
        // Keep-results are live until explicitly released (paper §3.1:
        // workers hold them "until the responsible scheduler signals the
        // data is no longer required") — and dynamic injection may
        // reference them arbitrarily far in the future (the Jacobi matrix
        // blocks), so a lost kept result is always recomputed.
        if self.specs.get(&job).map(|s| s.keep).unwrap_or(false) {
            return true;
        }
        // The producing segment anchors liveness, like the release horizon
        // (a result with no recorded consumer is not dead — an injection
        // may still reference it).  Under `Lagged` the whole lag window is
        // live: a lag-compliant injection may reference up to `lag`
        // segments back, so a lost result inside the window must be
        // recomputed — recovery mirrors the release horizon (DESIGN.md §6).
        let produced = self.produced_in.get(&job).copied().unwrap_or(0);
        let last = self.last_use.get(&job).copied().unwrap_or(produced).max(produced);
        let alive = match self.cfg.release {
            ReleasePolicy::Lagged { lag } => last + lag >= self.seg_idx,
            ReleasePolicy::AtShutdown => last >= self.seg_idx,
        };
        alive || self.in_final_segment(job)
    }

    fn queue_recovery(&mut self, job: JobId) {
        if !self.recovery.contains(&job) && !self.pending.contains(&job) {
            self.recovery.push_back(job);
        }
    }

    /// Assign jobs from the recovery queue whose inputs are available.
    fn try_recovery(&mut self) {
        let mut still_waiting = VecDeque::new();
        while let Some(job) = self.recovery.pop_front() {
            let ready = self
                .specs
                .get(&job)
                .map(|s| s.inputs.iter().all(|r| self.available.contains(&r.job)))
                .unwrap_or(false);
            if ready {
                self.assign(job);
            } else {
                still_waiting.push_back(job);
            }
        }
        self.recovery = still_waiting;
    }

    fn assign_or_defer(&mut self, job: JobId) {
        let ready = self
            .specs
            .get(&job)
            .map(|s| s.inputs.iter().all(|r| self.available.contains(&r.job)))
            .unwrap_or(false);
        if ready {
            self.assign(job);
        } else {
            // Normally impossible for static jobs (validation), but a lost
            // worker can invalidate inputs between segments.
            self.queue_recovery(job);
        }
    }

    /// At the close of segment `seg_idx`, free every result whose
    /// producing segment *and* last known reference lie at or before the
    /// horizon `seg_idx - lag` — the unified horizon arithmetic
    /// `last + lag <= horizon` shared with the dataflow executor
    /// (DESIGN.md §6).
    fn apply_barrier_release(&mut self) {
        let ReleasePolicy::Lagged { lag } = self.cfg.release else { return };
        if self.seg_idx < lag {
            return;
        }
        let horizon = self.seg_idx - lag;
        let candidates: Vec<JobId> = self
            .available
            .iter()
            .copied()
            .filter(|j| {
                // The producing segment anchors the horizon: a result with
                // no recorded consumer (one made for a future injection)
                // must survive the full lag window from where it was
                // produced, not from segment 0.
                let produced = self.produced_in.get(j).copied().unwrap_or(0);
                let last =
                    self.last_use.get(j).copied().unwrap_or(produced).max(produced);
                produced <= horizon && last <= horizon && !self.in_final_segment(*j)
            })
            .collect();
        for job in candidates {
            self.release_result(job);
        }
    }

    // ================================================= dataflow execution

    /// Dependency-DAG drive loop: build the graph once, then alternate
    /// between draining the ready set onto sub-schedulers and folding
    /// completion / injection / fault events back into the graph.
    fn drive_dataflow(&mut self) -> Result<()> {
        let all: Vec<(usize, JobSpec)> = self
            .segments
            .iter()
            .enumerate()
            .flat_map(|(idx, seg)| seg.iter().cloned().map(move |s| (idx, s)))
            .collect();
        for seg in &self.segments {
            self.metrics.segment_opened(seg.len());
            self.seg_outstanding.push(seg.len());
            self.seg_closed.push(false);
        }
        for (idx, spec) in all {
            self.graph.insert(spec, idx);
        }

        // With coalescing on the mailbox is drained whole per pass; each
        // drain is bounded so one endless storm cannot starve the
        // scheduling pass that would absorb it.
        let drain_cap = self
            .cfg
            .ctrl_batch
            .max_msgs
            .saturating_mul(self.cfg.subs.len().max(1))
            .max(1);
        loop {
            let pass = Instant::now();
            self.assign_ready();
            self.send_prefetch_hints();
            if self.pending.is_empty() {
                if self.graph.all_done() {
                    self.busy_us += pass.elapsed().as_micros() as u64;
                    break;
                }
                // Nothing in flight, nothing ready, graph not done: some
                // waiting node's inputs can never materialise.
                let report = self.graph.waiting_report();
                let (stuck, missing) = report
                    .first()
                    .cloned()
                    .unwrap_or((JobId(0), Vec::new()));
                let missing: Vec<String> =
                    missing.iter().map(|j| j.to_string()).collect();
                return Err(Error::JobFailed {
                    job: stuck,
                    msg: format!(
                        "dataflow stuck: missing inputs {:?}, {} jobs waiting",
                        missing,
                        report.len()
                    ),
                });
            }
            // Pass boundary: ship everything buffered before blocking.
            self.coal.flush_all(self.comm, self.metrics);
            self.busy_us += pass.elapsed().as_micros() as u64;
            if self.coal.enabled() {
                // Amortised pass (DESIGN.md §12): drain the whole mailbox,
                // fold every event into the graph, then run ONE release →
                // placement → dispatch pass for the batch (the loop head).
                let wait = Instant::now();
                let envs = self.recv_drain_event(drain_cap)?;
                self.idle_us += wait.elapsed().as_micros() as u64;
                let work = Instant::now();
                let mut any_done = false;
                for env in envs {
                    let from = env.src;
                    any_done |= self.handle_dataflow_event(from, env.into_user())?;
                }
                if any_done {
                    self.apply_dataflow_release();
                }
                self.busy_us += work.elapsed().as_micros() as u64;
            } else {
                // PR 5 control plane: one message, one full pass.
                let wait = Instant::now();
                let env = self.recv_event()?;
                self.idle_us += wait.elapsed().as_micros() as u64;
                let work = Instant::now();
                let from = env.src;
                if self.handle_dataflow_event(from, env.into_user())? {
                    self.apply_dataflow_release();
                }
                self.busy_us += work.elapsed().as_micros() as u64;
            }
            self.hardening_pass()?;
        }

        // Close metric entries that never drained (empty injected gaps).
        for (idx, closed) in self.seg_closed.iter_mut().enumerate() {
            if !*closed {
                *closed = true;
                self.metrics.segment_closed_idx(idx);
            }
        }
        Ok(())
    }

    /// Speculative input prefetch (DESIGN.md §7): for every `Waiting` node
    /// that just reached all-inputs-but-one materialised, predict its
    /// assignment target with the same look-ahead placement [`Self::assign`]
    /// will use and hint that scheduler to pull the remote chunks now —
    /// transfer overlaps the last producer's execution, and the eventual
    /// assignment finds its inputs warm in the target's store.
    fn send_prefetch_hints(&mut self) {
        let candidates = self.graph.take_prefetch_candidates();
        if !self.cfg.prefetch || candidates.is_empty() {
            return;
        }
        for job in candidates {
            // One hint per open window: the entry is cleared when the job
            // is assigned (hit or cancel) or re-enters after a loss, so a
            // job whose window re-opens can be hinted afresh — and a wrong
            // prediction costs one redundant (and now cancelled) transfer.
            if self.prefetch_hints.contains_key(&job) {
                continue;
            }
            let Some(spec) = self.specs.get(&job) else { continue };
            let threads = spec.threads;
            let lookahead: Vec<JobSpec> = self
                .graph
                .consumers_of(job)
                .iter()
                .filter_map(|c| self.specs.get(c))
                .cloned()
                .collect();
            let target = self.place(spec, &lookahead);
            let mut seen = HashSet::new();
            let sources: Vec<SourceLoc> = spec
                .inputs
                .iter()
                .filter(|r| self.available.contains(&r.job) && seen.insert(r.job))
                .filter_map(|r| self.owners.get(&r.job).copied())
                .filter(|loc| loc.owner != target)
                .collect();
            if sources.is_empty() {
                continue; // everything already local to the prediction
            }
            self.prefetch_hints
                .insert(job, (target, sources.iter().map(|l| l.job).collect()));
            self.metrics.prefetch_sent();
            self.coal.send(
                self.comm,
                self.metrics,
                target,
                FwMsg::Prefetch { job, threads, sources },
            );
        }
    }

    /// The master's placement decision for `spec` (with look-ahead
    /// successors): comm-aware pricing when the knob is on, the PR 4
    /// byte-affinity policy otherwise.  Shared by real assignment and the
    /// prefetch target predictor so both always agree.
    fn place(&self, spec: &JobSpec, lookahead: &[JobSpec]) -> Rank {
        let comm: Option<&dyn TransferEstimate> = if self.cfg.comm_aware {
            Some(self.cfg.comm.as_ref())
        } else {
            None
        };
        // §16 memory pressure: near-budget subs look expensive.  `None`
        // (knob unset) passes the untouched est_load straight through.
        let pressured = apply_memory_pressure(
            &self.est_load,
            &self.stored_bytes,
            self.cfg.memory_budget_bytes,
        );
        choose_scheduler_policy(
            spec,
            lookahead,
            &self.owners,
            &self.result_bytes,
            &self.load,
            pressured.as_ref().unwrap_or(&self.est_load),
            &self.cfg.subs,
            comm,
        )
    }

    /// Total known bytes of `spec`'s distinct inputs (the size term of the
    /// µs/byte cost normalisation; 0 when nothing is known).
    fn input_bytes_of(&self, spec: &JobSpec) -> u64 {
        let mut seen = HashSet::new();
        spec.inputs
            .iter()
            .filter(|r| seen.insert(r.job))
            .filter_map(|r| self.result_bytes.get(&r.job))
            .sum()
    }

    /// Drain the graph's ready set onto the cluster.
    ///
    /// With coalescing on the whole frontier is placed in one bulk pass,
    /// heaviest estimated cost first (LPT over the per-sub outstanding
    /// cost, DESIGN.md §12): each job's estimate is computed once here and
    /// handed to [`Self::assign_with_est`], so big jobs claim targets
    /// before small ones fill the gaps.  With it off, the PR 5 take-ready
    /// order is preserved exactly.
    fn assign_ready(&mut self) {
        let ready = self.graph.take_ready();
        if ready.is_empty() {
            return;
        }
        let ests: Vec<(JobId, u64)> =
            ready.iter().map(|&j| (j, self.estimate_cost(j))).collect();
        let ordered = if self.coal.enabled() && ests.len() > 1 {
            bulk_assign_order(ests)
        } else {
            ests
        };
        // Constant across the drain: everything taken is Running, nothing
        // completes inside this loop.
        let frontier = self.graph.frontier();
        for (job, est) in ordered {
            self.metrics.job_ready(job);
            if let (Some(f), Some(seg)) = (frontier, self.graph.segment_of(job)) {
                if f < seg {
                    self.metrics.job_overlapped();
                }
            }
            self.assign_with_est(job, est);
        }
    }

    /// Fold one dataflow event into the graph.  Returns whether a
    /// completion was processed — the caller owes a release pass then
    /// ([`Self::apply_dataflow_release`] runs once per drained batch with
    /// coalescing on, once per completion with it off, DESIGN.md §12).
    fn handle_dataflow_event(&mut self, from: Rank, msg: FwMsg) -> Result<bool> {
        match msg {
            FwMsg::JobDone { job, kept_on, chunks, injections, output_bytes, exec_us } => {
                // Duplicate completion (losing speculative replica, or a
                // chaos-duplicated frame): tolerate it *before* touching
                // the cost model or injections — re-resolving an injection
                // batch would mint duplicate jobs (DESIGN.md §14).
                if self.tolerate_duplicate_done(from, job) {
                    return Ok(false);
                }
                self.settle_replicas(from, job);
                self.observe_cost(job, exec_us);
                // Insert injected nodes *before* completing the job, so a
                // producer's dependents (e.g. next-iteration consumers of a
                // kept matrix block) are visible to the release pass.
                if !injections.is_empty() {
                    self.insert_injections_dataflow(job, injections)?;
                }
                self.complete_job(from, job, kept_on, output_bytes);
                let _ = chunks;
                self.graph.on_done(job);
                self.note_segment_progress(job);
                // Exactly the results this completion may have made
                // releasable: the fresh one and its producers (whose
                // pending-consumer count just dropped).
                self.offer_release_candidates(job);
                Ok(true)
            }
            FwMsg::JobError { job, msg } => Err(Error::JobFailed { job, msg }),
            FwMsg::JobAborted { job, missing } => {
                // A losing replica whose inputs were already released after
                // the winner completed aborts late: nothing to recover
                // (DESIGN.md §14).
                if self.stale_replica_abort(job) {
                    return Ok(false);
                }
                self.count_abort(job, missing)?;
                self.forget_pending(job);
                self.reenter_dataflow(job);
                if !self.available.contains(&missing) && !self.pending.contains(&missing)
                {
                    // The referenced result is gone: recompute its producer
                    // (the graph re-readies the aborted job afterwards).
                    self.graph.on_result_lost(missing);
                    if self.graph.contains(missing) {
                        self.reenter_dataflow(missing);
                    }
                }
                Ok(false)
            }
            FwMsg::WorkerLostReport { lost, running, .. } => {
                // Tolerated post-recovery: if the reporting sub was itself
                // declared lost in the meantime, every step below is
                // idempotent (the results/jobs were already recovered by
                // `on_rank_lost`, DESIGN.md §14).
                for job in lost {
                    if self.available.remove(&job) {
                        self.credit_stored_bytes(job);
                    }
                    if let Some(loc) = self.owners.get_mut(&job) {
                        loc.kept_on = None;
                    }
                    self.graph.on_result_lost(job);
                    if self.still_needed_dataflow(job) {
                        self.metrics.job_recomputed();
                        self.reenter_dataflow(job);
                    }
                }
                for job in running {
                    if self.forget_pending(job) {
                        self.metrics.job_recomputed();
                        self.reenter_dataflow(job);
                    }
                }
                Ok(false)
            }
            FwMsg::Batch(msgs) => {
                // Coalesced frame from a sub: members fold in order; the
                // release debt aggregates across them.
                let mut any_done = false;
                for m in msgs {
                    any_done |= self.handle_dataflow_event(from, m)?;
                }
                Ok(any_done)
            }
            // Liveness reply to a heartbeat probe: the envelope's arrival
            // already refreshed the detector in `recv_event`; the payload
            // itself carries nothing (DESIGN.md §14).
            FwMsg::HeartbeatAck => Ok(false),
            // hypar-lint: L1 wildcard-ok — same routing contract as the
            // barrier handler: late fetch replies are tolerated silently,
            // anything else is a protocol bug dropped loudly in debug
            // builds (DESIGN.md §13).
            FwMsg::ResultData { .. } | FwMsg::ResultUnavailable { .. } => Ok(false),
            other => {
                log_unroutable("master/dataflow", &other);
                Ok(false)
            }
        }
    }

    /// Resolve an injection batch against the injecting job's segment and
    /// insert the new jobs as incremental graph nodes.
    fn insert_injections_dataflow(
        &mut self,
        from_job: JobId,
        injections: Vec<Injection>,
    ) -> Result<()> {
        let current = self.graph.segment_of(from_job).unwrap_or(0);
        let resolved = resolve_injections(
            injections,
            current,
            &mut self.next_id,
            |id| self.specs.contains_key(&id),
        )?;
        let old_len = self.segments.len();
        for batch in resolved {
            while self.segments.len() <= batch.segment_index {
                self.segments.push(Vec::new());
                self.metrics.segment_opened(0);
                self.seg_outstanding.push(0);
                self.seg_closed.push(false);
            }
            self.metrics.jobs_injected_into(batch.jobs.len(), batch.segment_index);
            for spec in batch.jobs {
                self.specs.insert(spec.id, spec.clone());
                self.produced_in.insert(spec.id, batch.segment_index);
                self.metrics.job_dependencies(spec.id, &distinct_inputs(&spec));
                for r in &spec.inputs {
                    let e = self
                        .last_use
                        .entry(r.job)
                        .or_insert(batch.segment_index);
                    *e = (*e).max(batch.segment_index);
                }
                self.seg_outstanding[batch.segment_index] += 1;
                self.segments[batch.segment_index].push(spec.clone());
                self.graph.insert(spec, batch.segment_index);
            }
        }
        if self.segments.len() > old_len && old_len > 0 {
            // The final segment moved: jobs of the previous final segment
            // lost their release exemption — offer them to the next pass.
            let ex_final: Vec<JobId> =
                self.segments[old_len - 1].iter().map(|j| j.id).collect();
            self.release_candidates.extend(ex_final);
        }
        Ok(())
    }

    /// Re-enter a node for (re-)execution, keeping the per-segment
    /// outstanding counters consistent: only a `Done` node re-opens its
    /// segment (running/waiting nodes never left it).
    ///
    /// A re-entered node's outstanding prefetch hint is cancelled: the
    /// prediction was made against inputs that may no longer exist, and
    /// clearing the entry re-opens the hint window for the recovery pass.
    fn reenter_dataflow(&mut self, job: JobId) {
        if let Some((predicted, srcs)) = self.prefetch_hints.remove(&job) {
            self.cancel_prefetch(predicted, &srcs);
        }
        let was_done = self.graph.state(job) == Some(NodeState::Done);
        self.graph.reenter(job);
        if was_done {
            if let Some(seg) = self.graph.segment_of(job) {
                if let Some(c) = self.seg_outstanding.get_mut(seg) {
                    *c += 1;
                }
            }
        }
    }

    /// Segment-drain metrics bookkeeping for a completed job.
    fn note_segment_progress(&mut self, job: JobId) {
        let Some(seg) = self.graph.segment_of(job) else { return };
        if let Some(c) = self.seg_outstanding.get_mut(seg) {
            *c = c.saturating_sub(1);
            if *c == 0 {
                if let Some(flag) = self.seg_closed.get_mut(seg) {
                    *flag = true;
                }
                self.metrics.segment_closed_idx(seg);
            }
        }
    }

    fn still_needed_dataflow(&self, job: JobId) -> bool {
        // Keep-results always recompute (see still_needed_barrier).
        if self.specs.get(&job).map(|s| s.keep).unwrap_or(false) {
            return true;
        }
        if self.graph.has_pending_consumers(job) || self.in_final_segment(job) {
            return true;
        }
        // Under `Lagged`, a lost result still inside its lag window may be
        // referenced by a future lag-compliant injection: recompute it,
        // mirroring the release horizon (`last + lag <= frontier` frees —
        // so anything short of that horizon is still live, DESIGN.md §6).
        if let ReleasePolicy::Lagged { lag } = self.cfg.release {
            let produced = self.graph.segment_of(job).unwrap_or(0);
            let last =
                self.last_use.get(&job).copied().unwrap_or(produced).max(produced);
            if let Some(frontier) = self.graph.frontier() {
                return last + lag > frontier;
            }
        }
        false
    }

    /// Feed the release pass the results whose eligibility may have
    /// changed when `job` completed: its own fresh result and each of its
    /// producers (their pending-consumer count just dropped).
    fn offer_release_candidates(&mut self, job: JobId) {
        if !matches!(self.cfg.release, ReleasePolicy::Lagged { .. }) {
            return;
        }
        self.release_candidates.push(job);
        if let Some(spec) = self.specs.get(&job) {
            self.release_candidates.extend(distinct_inputs(spec));
        }
    }

    /// Dependency-count release: a result is freed once (a) every known
    /// out-edge has drained, and (b) its last known reference lies at
    /// least `lag` segments behind the dataflow frontier — the same
    /// horizon arithmetic as the barrier policy (`last + lag <= horizon`,
    /// DESIGN.md §6), with the frontier standing in for the closing
    /// segment, so both modes free a result at the same lag distance.
    ///
    /// The pass is **incremental**: it examines only the candidates
    /// offered by the completion event ([`Self::offer_release_candidates`],
    /// O(degree)) plus results previously parked on the lag horizon that
    /// the frontier just reached — never the whole available set.  A
    /// candidate that fails the consumer test is simply dropped: the
    /// completion of its last consumer will re-offer it.  A debug
    /// cross-check scans the available set and asserts nothing eligible
    /// was missed.
    fn apply_dataflow_release(&mut self) {
        let ReleasePolicy::Lagged { lag } = self.cfg.release else {
            self.release_candidates.clear();
            return;
        };
        let Some(frontier) = self.graph.frontier() else { return };
        // Results blocked only on the horizon, now inside it.
        while let Some((&key, _)) = self.lag_parked.range(..=frontier).next() {
            let unparked = self.lag_parked.remove(&key).unwrap_or_default();
            for j in unparked {
                self.parked.remove(&j);
                self.release_candidates.push(j);
            }
        }
        let candidates = std::mem::take(&mut self.release_candidates);
        for j in candidates {
            if !self.available.contains(&j)
                || self.in_final_segment(j)
                || self.graph.has_pending_consumers(j)
            {
                continue;
            }
            let produced = self.graph.segment_of(j).unwrap_or(0);
            let last = self.last_use.get(&j).copied().unwrap_or(produced).max(produced);
            if last + lag <= frontier {
                self.release_result(j);
                // The graph must see the result as gone so a late injected
                // consumer (a `lag`-contract violation) parks as Waiting
                // and surfaces as the deterministic "dataflow stuck" error
                // — mirroring the barrier executor's "recovery stuck" —
                // instead of being assigned against a freed source.
                self.graph.on_result_lost(j);
            } else if self.parked.insert(j) {
                // Consumers drained, horizon not reached: park until the
                // frontier arrives (re-verified then — an injection may
                // have pushed `last_use` forward or added a consumer).
                self.lag_parked.entry(last + lag).or_default().push(j);
            }
        }
        debug_assert!(
            self.dataflow_release_scan_missed().is_empty(),
            "incremental release pass missed eligible results: {:?}",
            self.dataflow_release_scan_missed()
        );
    }

    /// Debug cross-check of the incremental release pass: the original
    /// full scan over the available set, returning anything that is
    /// eligible right now and neither freed nor parked.  Only invoked from
    /// `debug_assert!` — release builds compile it out with the assert.
    fn dataflow_release_scan_missed(&self) -> Vec<JobId> {
        let ReleasePolicy::Lagged { lag } = self.cfg.release else {
            return Vec::new();
        };
        let Some(frontier) = self.graph.frontier() else { return Vec::new() };
        self.available
            .iter()
            .copied()
            .filter(|&j| {
                let produced = self.graph.segment_of(j).unwrap_or(0);
                let last =
                    self.last_use.get(&j).copied().unwrap_or(produced).max(produced);
                last + lag <= frontier
                    && !self.graph.has_pending_consumers_scan(j)
                    && !self.in_final_segment(j)
            })
            .collect()
    }

    // ====================================================== shared pieces

    /// Completion bookkeeping shared by both executors: pending/load
    /// accounting, owner update, result availability.
    fn complete_job(&mut self, from: Rank, job: JobId, kept_on: Option<Rank>, output_bytes: u64) {
        self.forget_pending(job);
        // `owners` was pre-set at assignment to the chosen sub; pin it to
        // the rank that actually completed (with speculative replicas the
        // latest assignment target may be the *losing* copy, DESIGN.md §14)
        // and update with the kept location.
        if let Some(loc) = self.owners.get_mut(&job) {
            loc.owner = from;
            loc.kept_on = kept_on;
        }
        // Charge the completing rank's stored-bytes ledger exactly once
        // per availability transition (§16 memory-pressure placement).
        if self.available.insert(job) {
            *self.stored_bytes.entry(from).or_default() += output_bytes;
        }
        self.result_bytes.insert(job, output_bytes);
        // A completed job starts a clean abort slate: the limit guards
        // against a single unrecoverable abort *cycle*, not against the
        // sum of independent recovery episodes a long fault-heavy run
        // accumulates (abort → recover → complete → lost → re-enter …).
        self.abort_counts.remove(&job);
    }

    /// Remove `job` from the in-flight set, crediting its scheduler's
    /// load (count and estimated cost). Returns whether it was in flight.
    fn forget_pending(&mut self, job: JobId) -> bool {
        if !self.pending.remove(&job) {
            return false;
        }
        if let Some(fl) = self.inflight.remove(&job) {
            // Straggler tracking charged every replica target; refund each
            // exactly what its dispatch charged (DESIGN.md §14).
            for (rank, est) in fl.targets {
                if let Some(l) = self.load.get_mut(&rank) {
                    *l = l.saturating_sub(1);
                }
                if est > 0 {
                    if let Some(l) = self.est_load.get_mut(&rank) {
                        *l = l.saturating_sub(est);
                    }
                }
            }
            self.est_charged.remove(&job);
            return true;
        }
        if let Some(loc) = self.owners.get(&job) {
            let owner = loc.owner;
            if let Some(l) = self.load.get_mut(&owner) {
                *l = l.saturating_sub(1);
            }
            // Refund exactly what assignment charged — the estimate
            // may have drifted since, so the charge is remembered, not
            // recomputed.
            if let Some(est) = self.est_charged.remove(&job) {
                if let Some(l) = self.est_load.get_mut(&owner) {
                    *l = l.saturating_sub(est);
                }
            }
        }
        true
    }

    /// Fold a completion's observed execution time into the cost model and
    /// record estimate-vs-actual accuracy (DESIGN.md §9).  `exec_us == 0`
    /// means "not measured" (e.g. a legacy kept-data ack) and is skipped.
    /// Under comm-aware placement the sample is additionally normalised
    /// per input byte (DESIGN.md §10), so kinds with variable input sizes
    /// estimate as µs/byte.
    fn observe_cost(&mut self, job: JobId, exec_us: u64) {
        if !self.cfg.cost_model || exec_us == 0 {
            return;
        }
        let Some(spec) = self.specs.get(&job) else { return };
        let func = spec.func.0;
        let est = self.costs.estimate_job_us(func);
        self.metrics.cost_observed(func, est, exec_us);
        if self.cfg.comm_aware {
            let bytes = self.input_bytes_of(spec);
            self.costs.record_job_sized(func, exec_us, bytes);
        } else {
            self.costs.record_job(func, exec_us);
        }
    }

    /// Cancel a mispredicted (or stale) prefetch hint: tell the predicted
    /// target to drop the copies it pulled (`ReleaseResult` per hinted
    /// source).  A source whose *owner* meanwhile became the predicted
    /// target is skipped — the copy there is the authoritative one now.
    fn cancel_prefetch(&mut self, predicted: Rank, srcs: &[JobId]) {
        for &src in srcs {
            if self.owners.get(&src).map(|l| l.owner) == Some(predicted) {
                continue;
            }
            self.metrics.prefetch_cancelled();
            self.coal.send(
                self.comm,
                self.metrics,
                predicted,
                FwMsg::ReleaseResult { job: src },
            );
        }
    }

    fn count_abort(&mut self, job: JobId, missing: JobId) -> Result<()> {
        let aborts = self.abort_counts.entry(job).or_insert(0);
        *aborts += 1;
        if *aborts > MAX_ABORTS_PER_JOB {
            return Err(Error::JobFailed {
                job,
                msg: format!(
                    "aborted {aborts} times waiting for result of {missing}; giving up"
                ),
            });
        }
        Ok(())
    }

    /// Does `job` belong to the (current) final segment?  O(1) via the
    /// producing-segment index — injections may append segments, so this
    /// is evaluated against the live segment list, never cached.
    fn in_final_segment(&self, job: JobId) -> bool {
        self.produced_in.get(&job).is_some_and(|&s| s + 1 == self.segments.len())
    }

    /// Estimated execution microseconds of `job` for placement charging:
    /// 0 while the model is off or the kind is cold (placement then
    /// degrades to pure queue length).  Comm-aware placement sizes the
    /// estimate by the job's input bytes (µs/byte normalisation,
    /// DESIGN.md §10).
    fn estimate_cost(&self, job: JobId) -> u64 {
        if !self.cfg.cost_model {
            return 0;
        }
        let Some(spec) = self.specs.get(&job) else { return 0 };
        let estimate = if self.cfg.comm_aware {
            self.costs
                .estimate_job_us_sized(spec.func.0, self.input_bytes_of(spec))
        } else {
            self.costs.estimate_job_us(spec.func.0)
        };
        estimate.map(|us| us.round().max(1.0) as u64).unwrap_or(0)
    }

    fn assign(&mut self, job: JobId) {
        let est = self.estimate_cost(job);
        self.assign_with_est(job, est);
    }

    /// Place and dispatch `job`, charging the precomputed cost estimate
    /// (shared by single assignment and the bulk LPT pass, which prices
    /// the whole frontier before placing any of it).
    fn assign_with_est(&mut self, job: JobId, est: u64) {
        let spec = self.specs.get(&job).expect("assigning unknown job").clone();
        // Look-ahead packing (dataflow): weigh where this job's known
        // successors' inputs live, so chains pack onto the scheduler
        // already holding their data.
        let lookahead: Vec<JobSpec> = if self.cfg.mode == ExecutionMode::Dataflow {
            self.graph
                .consumers_of(job)
                .iter()
                .filter_map(|c| self.specs.get(c))
                .cloned()
                .collect()
        } else {
            Vec::new()
        };
        let target = self.place(&spec, &lookahead);
        // Resolve the outstanding prefetch hint: a correct prediction is
        // consumed by this very assignment; a wrong one gets cancel hints
        // so the mispredicted copies don't linger until shutdown.
        if let Some((predicted, srcs)) = self.prefetch_hints.remove(&job) {
            if predicted != target {
                self.cancel_prefetch(predicted, &srcs);
            }
        }
        if est > 0 {
            self.est_charged.insert(job, est);
            *self.est_load.entry(target).or_default() += est;
        }
        let sources: Vec<SourceLoc> = spec
            .inputs
            .iter()
            .filter_map(|r| self.owners.get(&r.job).copied())
            .collect();
        let input_bytes = 0u64; // shipped bytes are accounted by comm stats
        self.metrics.job_assigned(job, input_bytes);
        self.owners.insert(
            job,
            SourceLoc { job, owner: target, kept_on: None },
        );
        *self.load.entry(target).or_default() += 1;
        self.pending.insert(job);
        if self.cfg.stragglers {
            // Arm the deadline clock for this dispatch (DESIGN.md §14).
            let deadline_us = self.deadline_us(est);
            let fl = self.inflight.entry(job).or_insert(Inflight {
                targets: Vec::new(),
                since: Instant::now(),
                deadline_us,
                tries: 0,
            });
            fl.targets.push((target, est));
            fl.since = Instant::now();
            fl.deadline_us = deadline_us;
            fl.tries += 1;
        }
        self.coal
            .send(self.comm, self.metrics, target, FwMsg::Assign { spec, sources });
    }

    /// Free `job`'s stored/kept result and drop the master-side location
    /// bookkeeping.  Broadcast to every sub-scheduler: the owner frees its
    /// store (and tells a retaining worker to drop its kept copy), and the
    /// others drop any *transient* copy they fetched as consumers or on a
    /// prefetch hint — under `Lagged`, the policy that exists to bound
    /// mid-run memory, those copies must not outlive the result.
    fn release_result(&mut self, job: JobId) {
        // Broadcast storms (a drained lag window frees many results at
        // once) are a main coalescing payload: one frame per sub instead
        // of one send per (result, sub) pair.
        for i in 0..self.cfg.subs.len() {
            let s = self.cfg.subs[i];
            self.coal
                .send(self.comm, self.metrics, s, FwMsg::ReleaseResult { job });
        }
        if self.available.remove(&job) {
            self.credit_stored_bytes(job);
        }
        self.owners.remove(&job);
        self.metrics.result_released();
    }

    /// Credit a result's bytes back to its owner's stored-bytes ledger —
    /// call exactly on the available → not-available transition, before
    /// the `owners` entry is dropped (§16 memory-pressure placement).
    fn credit_stored_bytes(&mut self, job: JobId) {
        let Some(loc) = self.owners.get(&job) else { return };
        let bytes = self.result_bytes.get(&job).copied().unwrap_or(0);
        if let Some(s) = self.stored_bytes.get_mut(&loc.owner) {
            *s = s.saturating_sub(bytes);
        }
    }

    fn collect_final_results(&mut self) -> Result<BTreeMap<JobId, FunctionData>> {
        let me = self.comm.rank();
        let finals: Vec<JobId> = self
            .segments
            .last()
            .map(|s| s.iter().map(|j| j.id).collect())
            .unwrap_or_default();
        let mut expected = HashSet::new();
        for job in &finals {
            // A final job with no recorded owner was released or never
            // completed: silently omitting it would hand the caller a
            // partial result map that looks successful.  Fail loudly.
            let Some(loc) = self.owners.get(job) else {
                return Err(Error::ResultNotAvailable(*job));
            };
            let owner = loc.owner;
            self.coal.send(
                self.comm,
                self.metrics,
                owner,
                FwMsg::FetchResult { job: *job, range: ChunkRange::All, reply_to: me },
            );
            expected.insert(*job);
        }
        // The loop below blocks: everything buffered must be on the wire.
        self.coal.flush_all(self.comm, self.metrics);
        let mut out = BTreeMap::new();
        let mut queue: VecDeque<FwMsg> = VecDeque::new();
        // Hardened collection (DESIGN.md §14): a reply from a lost or
        // chaos-afflicted owner may never arrive, so the wait stays timed,
        // keeps the heartbeat detector ticking, and periodically re-issues
        // the fetches still outstanding.
        let mut idle_polls = 0u32;
        while !expected.is_empty() {
            let msg = match queue.pop_front() {
                Some(m) => m,
                None if self.timed_recv() => {
                    match self
                        .comm
                        .recv_match_timeout(Match::any(), self.poll_interval())
                        .map_err(|_| Error::WorldShutdown(me))?
                    {
                        Some(env) => {
                            self.note_heard(env.src);
                            idle_polls = 0;
                            env.into_user()
                        }
                        None => {
                            self.hb_tick()?;
                            idle_polls += 1;
                            if idle_polls % 4 == 0 {
                                // Re-fetch what is still missing: the
                                // original request or its reply may have
                                // been dropped on the floor.
                                for job in expected.iter().copied().collect::<Vec<_>>() {
                                    let Some(loc) = self.owners.get(&job) else {
                                        return Err(Error::ResultNotAvailable(job));
                                    };
                                    let owner = loc.owner;
                                    self.coal.send(
                                        self.comm,
                                        self.metrics,
                                        owner,
                                        FwMsg::FetchResult {
                                            job,
                                            range: ChunkRange::All,
                                            reply_to: me,
                                        },
                                    );
                                }
                            }
                            self.coal.flush_all(self.comm, self.metrics);
                            continue;
                        }
                    }
                }
                None => self
                    .comm
                    .recv()
                    .map_err(|_| Error::WorldShutdown(me))?
                    .into_user(),
            };
            match msg {
                FwMsg::Batch(msgs) => queue.extend(msgs),
                FwMsg::ResultData { job, data } => {
                    if expected.remove(&job) {
                        out.insert(job, data);
                    }
                }
                FwMsg::ResultUnavailable { job } => {
                    return Err(Error::ResultNotAvailable(job));
                }
                // Late liveness replies are expected while collecting.
                FwMsg::HeartbeatAck => {}
                // hypar-lint: L1 wildcard-ok — completion-shaped
                // stragglers can legally race the final collection (a
                // sub's liveness pass may still report a lost worker after
                // the last job finished); the run's outcome is already
                // decided, so they are acknowledged and dropped.  Anything
                // else is a protocol bug, loud in debug builds.
                FwMsg::JobDone { .. }
                | FwMsg::JobError { .. }
                | FwMsg::JobAborted { .. }
                | FwMsg::WorkerLostReport { .. } => {}
                other => log_unroutable("master/collect", &other),
            }
        }
        Ok(out)
    }

    fn broadcast_shutdown(&mut self) {
        for i in 0..self.cfg.subs.len() {
            let s = self.cfg.subs[i];
            // Flushes the sub's buffer first: a `Shutdown` must never
            // overtake buffered control traffic to the same sub.
            let _ = self
                .coal
                .send_now(self.comm, self.metrics, s, FwMsg::Shutdown);
        }
        // Ranks declared lost also get a shutdown: a false positive (a
        // healthy in-process thread the detector gave up on) must still
        // exit so the framework's join completes; a genuinely dead rank
        // makes the send error, which is ignored (DESIGN.md §14).
        for i in 0..self.lost_ranks.len() {
            let s = self.lost_ranks[i];
            let _ = self
                .coal
                .send_now(self.comm, self.metrics, s, FwMsg::Shutdown);
        }
    }

    // ============================================= failure hardening (§14)

    /// Whether the event loop must poll (heartbeats or straggler scans
    /// need periodic attention) instead of blocking indefinitely.
    fn timed_recv(&self) -> bool {
        self.hb.is_some() || self.cfg.stragglers
    }

    /// How long one blocking wait may last when [`Self::timed_recv`]: the
    /// heartbeat interval paces both beats and deadline scans; without
    /// heartbeats a fixed straggler poll does.
    fn poll_interval(&self) -> Duration {
        if self.hb.is_some() {
            self.cfg.heartbeat_interval
        } else {
            STRAGGLER_POLL
        }
    }

    /// Refresh the failure detector for a rank we just heard from.
    fn note_heard(&mut self, src: Rank) {
        if let Some(hb) = &mut self.hb {
            hb.note_heard(src, Instant::now());
        }
    }

    /// Receive one event.  With hardening off this is the verbatim
    /// blocking receive of PR 7; with it on, the wait is sliced into
    /// poll-interval chunks and each empty slice runs a hardening pass
    /// (beats out, deadlines scanned) before blocking again.
    fn recv_event(&mut self) -> Result<Envelope<FwMsg>> {
        let me = self.comm.rank();
        if !self.timed_recv() {
            return self.comm.recv().map_err(|_| Error::WorldShutdown(me));
        }
        loop {
            match self
                .comm
                .recv_match_timeout(Match::any(), self.poll_interval())
                .map_err(|_| Error::WorldShutdown(me))?
            {
                Some(env) => {
                    self.note_heard(env.src);
                    return Ok(env);
                }
                None => {
                    self.hardening_pass()?;
                    // Beats and speculative re-dispatches buffered by the
                    // pass must not wait for the next organic flush.
                    self.coal.flush_all(self.comm, self.metrics);
                }
            }
        }
    }

    /// Drain up to `cap` events: with hardening off this is the verbatim
    /// `recv_drain` of PR 7; with it on, one hardened blocking receive
    /// plus a non-blocking drain — the exact same one-blocking-call
    /// contract.
    fn recv_drain_event(&mut self, cap: usize) -> Result<Vec<Envelope<FwMsg>>> {
        let me = self.comm.rank();
        if !self.timed_recv() {
            return self
                .comm
                .recv_drain(cap)
                .map_err(|_| Error::WorldShutdown(me));
        }
        let mut envs = Vec::with_capacity(4);
        envs.push(self.recv_event()?);
        while envs.len() < cap {
            match self.comm.try_recv().map_err(|_| Error::WorldShutdown(me))? {
                Some(env) => {
                    self.note_heard(env.src);
                    envs.push(env);
                }
                None => break,
            }
        }
        Ok(envs)
    }

    /// One hardening pass: tick the heartbeat detector (beats out, losses
    /// in), then scan in-flight jobs against their deadlines.  Both are
    /// immediate no-ops with the knobs off.
    fn hardening_pass(&mut self) -> Result<()> {
        self.hb_tick()?;
        self.scan_stragglers()
    }

    /// Drive the heartbeat detector one step: record fresh misses, send
    /// the probes it says are due, recover the peers it declares lost.
    fn hb_tick(&mut self) -> Result<()> {
        let tick = match self.hb.as_mut() {
            Some(hb) => hb.on_tick(Instant::now()),
            None => return Ok(()),
        };
        if tick.new_misses > 0 {
            self.metrics.heartbeat_missed(tick.new_misses);
        }
        for r in tick.beat {
            self.coal.send(self.comm, self.metrics, r, FwMsg::Heartbeat);
        }
        for r in tick.lost {
            self.on_rank_lost(r)?;
        }
        Ok(())
    }

    /// The deadline for a dispatch with estimated cost `est` µs: the cost
    /// model's estimate scaled by the straggler factor, floored by the
    /// cold-start deadline (an unknown kind must not be declared late
    /// after 0 µs, DESIGN.md §14).
    fn deadline_us(&self, est: u64) -> u64 {
        ((est as f64 * self.cfg.straggler_factor) as u64).max(self.cfg.straggler_cold_us)
    }

    /// Scan in-flight jobs for blown deadlines and speculatively re-place
    /// each overdue one.
    fn scan_stragglers(&mut self) -> Result<()> {
        if !self.cfg.stragglers || self.inflight.is_empty() {
            return Ok(());
        }
        let now = Instant::now();
        let overdue: Vec<JobId> = self
            .inflight
            .iter()
            .filter(|(_, fl)| {
                now.duration_since(fl.since).as_micros() as u64 >= fl.deadline_us
            })
            .map(|(&job, _)| job)
            .collect();
        for job in overdue {
            self.dispatch_replica(job)?;
        }
        Ok(())
    }

    /// Dispatch one more copy of an overdue job (first completion wins,
    /// DESIGN.md §14).  Prefers a sub that has not been tried yet; when
    /// all have been, re-sends to the best of the full set (the original
    /// `Assign` itself may have been dropped).  Jobs whose inputs are
    /// currently being recomputed are skipped — the next scan re-offers
    /// them without burning a try.
    fn dispatch_replica(&mut self, job: JobId) -> Result<()> {
        let Some(fl) = self.inflight.get(&job) else { return Ok(()) };
        if fl.tries >= MAX_SPECULATIVE_TRIES {
            return Err(self.degraded(format!(
                "job {job:?} missed its deadline {} times",
                fl.tries
            )));
        }
        let Some(spec) = self.specs.get(&job).cloned() else { return Ok(()) };
        if !spec.inputs.iter().all(|r| self.available.contains(&r.job)) {
            return Ok(());
        }
        let tried: Vec<Rank> = fl.targets.iter().map(|&(r, _)| r).collect();
        let mut candidates: Vec<Rank> = self
            .cfg
            .subs
            .iter()
            .copied()
            .filter(|r| !tried.contains(r))
            .collect();
        if candidates.is_empty() {
            candidates = self.cfg.subs.clone();
        }
        let est = self.estimate_cost(job);
        let comm: Option<&dyn TransferEstimate> = if self.cfg.comm_aware {
            Some(self.cfg.comm.as_ref())
        } else {
            None
        };
        let pressured = apply_memory_pressure(
            &self.est_load,
            &self.stored_bytes,
            self.cfg.memory_budget_bytes,
        );
        let target = choose_scheduler_policy(
            &spec,
            &[],
            &self.owners,
            &self.result_bytes,
            &self.load,
            pressured.as_ref().unwrap_or(&self.est_load),
            &candidates,
            comm,
        );
        if est > 0 {
            self.est_charged.insert(job, est);
            *self.est_load.entry(target).or_default() += est;
        }
        let sources: Vec<SourceLoc> = spec
            .inputs
            .iter()
            .filter_map(|r| self.owners.get(&r.job).copied())
            .collect();
        self.owners
            .insert(job, SourceLoc { job, owner: target, kept_on: None });
        *self.load.entry(target).or_default() += 1;
        self.metrics.speculative_reexec();
        // Each retry stretches the next deadline by the backoff: a run
        // that is merely slow converges instead of replica-storming.
        let deadline =
            self.deadline_us(est) + fl.tries as u64 * self.cfg.job_retry_backoff_us;
        let fl = self.inflight.get_mut(&job).expect("checked above");
        fl.targets.push((target, est));
        fl.since = Instant::now();
        fl.deadline_us = deadline;
        fl.tries += 1;
        self.coal
            .send(self.comm, self.metrics, target, FwMsg::Assign { spec, sources });
        Ok(())
    }

    /// A `JobDone` for a job that is no longer pending but already
    /// available is a duplicate (losing replica or duplicated frame):
    /// release the loser's copy and swallow the event.
    fn tolerate_duplicate_done(&mut self, from: Rank, job: JobId) -> bool {
        if !self.cfg.stragglers
            || self.pending.contains(&job)
            || !self.available.contains(&job)
        {
            return false;
        }
        self.release_losing_copy(from, job);
        true
    }

    /// On the winning completion: cancel every other replica still out
    /// (its sub drops queued copies and swallows a racing completion) and
    /// record a speculative win if the winner was not the original target.
    fn settle_replicas(&mut self, from: Rank, job: JobId) {
        if !self.cfg.stragglers {
            return;
        }
        let Some(fl) = self.inflight.get(&job) else { return };
        if fl.targets.len() > 1 && fl.targets.first().map(|&(r, _)| r) != Some(from) {
            self.metrics.speculative_win();
        }
        let losers: Vec<Rank> = fl
            .targets
            .iter()
            .map(|&(r, _)| r)
            .filter(|&r| r != from && self.cfg.subs.contains(&r))
            .collect();
        for r in losers {
            self.coal
                .send(self.comm, self.metrics, r, FwMsg::ReleaseResult { job });
        }
    }

    /// Tell a losing replica's sub to drop its copy of `job`'s result —
    /// unless `from` *is* the recorded owner (then the "duplicate" was a
    /// chaos-duplicated frame of the winning completion and the copy is
    /// authoritative) or `from` was since declared lost.
    fn release_losing_copy(&mut self, from: Rank, job: JobId) {
        if self.owners.get(&job).map(|l| l.owner) == Some(from)
            || !self.cfg.subs.contains(&from)
        {
            return;
        }
        self.coal
            .send(self.comm, self.metrics, from, FwMsg::ReleaseResult { job });
    }

    /// A `JobAborted` from a losing replica whose inputs were released
    /// after the winner completed: the job is done, nothing to recover.
    fn stale_replica_abort(&self, job: JobId) -> bool {
        self.cfg.stragglers
            && self.available.contains(&job)
            && !self.pending.contains(&job)
    }

    /// Every rank currently holding a dispatch of `job`.
    fn assigned_ranks(&self, job: JobId) -> Vec<Rank> {
        if let Some(fl) = self.inflight.get(&job) {
            return fl.targets.iter().map(|&(r, _)| r).collect();
        }
        self.owners.get(&job).map(|l| vec![l.owner]).unwrap_or_default()
    }

    /// Declare `rank` dead and recover everything it held: its results
    /// re-enter the graph, its pending dispatches are re-queued, and its
    /// load counters vanish.  Fails the run with [`Error::Degraded`] once
    /// losses exceed `max_rank_losses` (or no subs survive).
    fn on_rank_lost(&mut self, rank: Rank) -> Result<()> {
        if !self.cfg.subs.contains(&rank) {
            return Ok(()); // already processed (duplicate detection path)
        }
        self.metrics.rank_lost();
        self.lost_ranks.push(rank);
        if let Some(hb) = &mut self.hb {
            hb.remove(rank);
        }
        self.cfg.subs.retain(|&r| r != rank);
        self.load.remove(&rank);
        self.est_load.remove(&rank);
        self.stored_bytes.remove(&rank);
        if self.lost_ranks.len() > self.cfg.max_rank_losses {
            return Err(self.degraded(format!(
                "rank {rank:?} lost; {} losses exceed max_rank_losses={}",
                self.lost_ranks.len(),
                self.cfg.max_rank_losses
            )));
        }
        if self.cfg.subs.is_empty() {
            return Err(self.degraded(format!(
                "rank {rank:?} lost; no sub-schedulers survive"
            )));
        }
        // Results the dead rank owned are gone: their consumers must wait
        // for a recompute.  Each mode's existing single-result recovery
        // path is reused verbatim (graph re-entry vs recovery queue).
        let dataflow = self.cfg.mode == ExecutionMode::Dataflow;
        let lost_results: Vec<JobId> = self
            .owners
            .iter()
            .filter(|(_, loc)| loc.owner == rank)
            .map(|(&j, _)| j)
            .filter(|j| self.available.contains(j))
            .collect();
        for job in lost_results {
            self.available.remove(&job);
            self.owners.remove(&job);
            if dataflow {
                self.graph.on_result_lost(job);
                if self.still_needed_dataflow(job) {
                    self.metrics.job_recomputed();
                    self.reenter_dataflow(job);
                }
            } else if self.still_needed_barrier(job) {
                self.metrics.job_recomputed();
                self.queue_recovery(job);
            }
        }
        // Pending dispatches on the dead rank: survivors with a live
        // replica just lose that target; the rest re-enter for a fresh
        // assignment.
        let stranded: Vec<JobId> = self
            .pending
            .iter()
            .copied()
            .filter(|&j| self.assigned_ranks(j).contains(&rank))
            .collect();
        for job in stranded {
            let survivors: Vec<(Rank, u64)> = self
                .inflight
                .get(&job)
                .map(|fl| {
                    fl.targets
                        .iter()
                        .copied()
                        .filter(|&(r, _)| r != rank)
                        .collect()
                })
                .unwrap_or_default();
            if !survivors.is_empty() {
                if let Some(fl) = self.inflight.get_mut(&job) {
                    fl.targets = survivors;
                }
                continue; // a live replica is still running it
            }
            self.forget_pending(job);
            self.metrics.job_recomputed();
            if dataflow {
                self.reenter_dataflow(job);
            } else {
                self.queue_recovery(job);
            }
        }
        if !dataflow {
            self.try_recovery();
        }
        Ok(())
    }

    /// Build the structured give-up error: what the run completed, what
    /// was still outstanding, and why it stopped (DESIGN.md §14).
    fn degraded(&self, reason: String) -> Error {
        let mut outstanding: Vec<JobId> = self
            .pending
            .iter()
            .chain(self.recovery.iter())
            .copied()
            .collect();
        outstanding.sort_unstable();
        outstanding.dedup();
        Error::Degraded(Box::new(FailureReport {
            reason,
            ranks_lost: self.lost_ranks.clone(),
            completed_jobs: self.available.len(),
            outstanding_jobs: outstanding,
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{CostModel, World};

    fn with_master(f: impl FnOnce(&mut Master<'_>)) {
        with_master_and_sub(|m, _| f(m));
    }

    /// Master plus one live "sub-scheduler" mailbox so tests can observe
    /// what the master actually sends.  Coalescing is off here so sends
    /// are immediately observable; [`with_batching_master_and_sub`] is the
    /// buffered variant.
    fn with_master_and_sub(f: impl FnOnce(&mut Master<'_>, &mut Comm<FwMsg>)) {
        let ctrl = CtrlBatchCfg { enabled: false, ..CtrlBatchCfg::default() };
        with_master_and_sub_ctrl(ctrl, f);
    }

    fn with_batching_master_and_sub(f: impl FnOnce(&mut Master<'_>, &mut Comm<FwMsg>)) {
        with_master_and_sub_ctrl(CtrlBatchCfg::default(), f);
    }

    fn with_master_and_sub_ctrl(
        ctrl: CtrlBatchCfg,
        f: impl FnOnce(&mut Master<'_>, &mut Comm<FwMsg>),
    ) {
        let world: World<FwMsg> = World::new(CostModel::default());
        let mut comm = world.add_rank();
        let mut sub = world.add_rank();
        let metrics = MetricsCollector::new();
        let cfg = MasterConfig {
            subs: vec![sub.rank()],
            release: ReleasePolicy::AtShutdown,
            mode: ExecutionMode::Dataflow,
            prefetch: true,
            cost_model: true,
            cost_ewma_alpha: 0.3,
            comm_aware: true,
            comm: world.calibration(),
            ctrl_batch: ctrl,
            // Hardening off: these tests pin the PR 7 behaviour.
            heartbeats: false,
            heartbeat_interval: Duration::from_millis(200),
            heartbeat_miss_limit: 15,
            stragglers: false,
            straggler_factor: 16.0,
            straggler_cold_us: 2_000_000,
            max_rank_losses: 4,
            job_retry_backoff_us: 250_000,
            memory_budget_bytes: 0,
        };
        let mut m = Master::new(&mut comm, cfg, &metrics);
        f(&mut m, &mut sub);
    }

    #[test]
    fn stored_bytes_ledger_charges_once_and_credits_on_release_and_loss() {
        with_master(|m| {
            let sub = m.cfg.subs[0];
            m.owners
                .insert(JobId(1), SourceLoc { job: JobId(1), owner: sub, kept_on: None });
            m.complete_job(sub, JobId(1), None, 4096);
            assert_eq!(m.stored_bytes.get(&sub).copied(), Some(4096));
            // A duplicate completion must not double-charge the ledger.
            m.complete_job(sub, JobId(1), None, 4096);
            assert_eq!(m.stored_bytes.get(&sub).copied(), Some(4096));
            m.release_result(JobId(1));
            assert_eq!(m.stored_bytes.get(&sub).copied(), Some(0));
            // Loss after a fresh completion credits through the same path.
            m.owners
                .insert(JobId(2), SourceLoc { job: JobId(2), owner: sub, kept_on: None });
            m.complete_job(sub, JobId(2), None, 512);
            assert_eq!(m.stored_bytes.get(&sub).copied(), Some(512));
            assert!(m.available.remove(&JobId(2)));
            m.credit_stored_bytes(JobId(2));
            assert_eq!(m.stored_bytes.get(&sub).copied(), Some(0));
        });
    }

    #[test]
    fn abort_counter_resets_when_a_job_completes() {
        // A job may abort up to the limit within ONE recovery episode; a
        // completion wipes the slate so a later, independent episode (the
        // job re-entered after worker loss) gets the full budget again.
        with_master(|m| {
            let job = JobId(1);
            for _ in 0..MAX_ABORTS_PER_JOB {
                m.count_abort(job, JobId(2)).expect("within budget");
            }
            let sub = m.cfg.subs[0];
            m.complete_job(sub, job, None, 0);
            for _ in 0..MAX_ABORTS_PER_JOB {
                m.count_abort(job, JobId(2))
                    .expect("budget must reset across completions");
            }
            assert!(
                m.count_abort(job, JobId(2)).is_err(),
                "limit still enforced within one episode"
            );
        });
    }

    #[test]
    fn cost_model_charges_est_load_on_assign_and_refunds_on_completion() {
        with_master_and_sub(|m, sub| {
            let target = m.cfg.subs[0];
            // Warm the table: one observed 1000 µs job of kind 5.
            m.specs.insert(JobId(1), JobSpec::new(1, 5, 1));
            m.observe_cost(JobId(1), 1000);
            assert_eq!(m.costs.estimate_job_us(5), Some(1000.0));
            // Assigning another kind-5 job charges the target's estimated
            // outstanding cost...
            m.specs.insert(JobId(2), JobSpec::new(2, 5, 1));
            m.assign(JobId(2));
            assert_eq!(m.est_load.get(&target).copied(), Some(1000));
            assert_eq!(m.est_charged.get(&JobId(2)).copied(), Some(1000));
            // ...and completion refunds exactly that charge.
            m.complete_job(target, JobId(2), None, 0);
            assert_eq!(m.est_load.get(&target).copied(), Some(0));
            assert!(m.est_charged.is_empty());
            // A cold kind charges nothing (placement degrades to queue
            // length) and the refund bookkeeping stays balanced.
            m.specs.insert(JobId(3), JobSpec::new(3, 9, 1));
            m.assign(JobId(3));
            assert!(m.est_charged.is_empty());
            // Drain the Assign messages so the world can shut down clean.
            while sub.try_recv().unwrap().is_some() {}
        });
    }

    #[test]
    fn mispredicted_prefetch_sends_cancel_hints() {
        with_master_and_sub(|m, sub| {
            let predicted = m.cfg.subs[0];
            let elsewhere = Rank(predicted.0 + 100);
            // Source 3 lives elsewhere: cancelling the hint must release
            // the predicted target's pulled copy.
            m.owners.insert(
                JobId(3),
                SourceLoc { job: JobId(3), owner: elsewhere, kept_on: None },
            );
            // Source 4 is now *owned* by the predicted target (recomputed
            // there after a loss): releasing it would free live data.
            m.owners.insert(
                JobId(4),
                SourceLoc { job: JobId(4), owner: predicted, kept_on: None },
            );
            m.cancel_prefetch(predicted, &[JobId(3), JobId(4)]);
            let env = sub.try_recv().unwrap().expect("cancel hint sent");
            match env.into_user() {
                FwMsg::ReleaseResult { job } => assert_eq!(job, JobId(3)),
                other => panic!("expected ReleaseResult, got {other:?}"),
            }
            assert!(sub.try_recv().unwrap().is_none(), "owned source must not be released");
        });
    }

    #[test]
    fn reentry_clears_and_cancels_the_prefetch_hint() {
        with_master_and_sub(|m, sub| {
            let predicted = m.cfg.subs[0];
            let elsewhere = Rank(predicted.0 + 100);
            m.owners.insert(
                JobId(7),
                SourceLoc { job: JobId(7), owner: elsewhere, kept_on: None },
            );
            m.prefetch_hints.insert(JobId(5), (predicted, vec![JobId(7)]));
            m.reenter_dataflow(JobId(5));
            assert!(m.prefetch_hints.is_empty(), "hint window must re-open");
            let env = sub.try_recv().unwrap().expect("cancel hint sent on re-entry");
            assert!(matches!(env.into_user(), FwMsg::ReleaseResult { job } if job == JobId(7)));
        });
    }

    #[test]
    fn batched_assigns_coalesce_into_one_wire_frame() {
        // With ctrl batching on, back-to-back Assigns to the same sub stay
        // buffered until the pass-boundary flush, then travel as ONE Batch
        // frame whose members preserve send order (DESIGN.md §12).
        with_batching_master_and_sub(|m, sub| {
            m.specs.insert(JobId(1), JobSpec::new(1, 5, 1));
            m.specs.insert(JobId(2), JobSpec::new(2, 5, 1));
            m.assign(JobId(1));
            m.assign(JobId(2));
            assert!(
                sub.try_recv().unwrap().is_none(),
                "assigns must buffer until the pass boundary"
            );
            m.coal.flush_all(m.comm, m.metrics);
            let env = sub.try_recv().unwrap().expect("flushed batch");
            match env.into_user() {
                FwMsg::Batch(msgs) => {
                    assert_eq!(msgs.len(), 2);
                    assert!(
                        matches!(&msgs[0], FwMsg::Assign { spec, .. } if spec.id == JobId(1))
                    );
                    assert!(
                        matches!(&msgs[1], FwMsg::Assign { spec, .. } if spec.id == JobId(2))
                    );
                }
                other => panic!("expected Batch, got {other:?}"),
            }
            assert!(sub.try_recv().unwrap().is_none(), "exactly one frame");
        });
    }

    #[test]
    fn missing_final_result_is_an_error_not_a_partial_map() {
        // A final-segment job with no owner entry (released / never
        // completed) must fail the collection loudly instead of silently
        // returning a partial result map.
        with_master(|m| {
            m.segments = vec![vec![JobSpec::new(1, 1, 1), JobSpec::new(2, 1, 1)]];
            m.produced_in.insert(JobId(1), 0);
            m.produced_in.insert(JobId(2), 0);
            // No owners recorded at all: the very first final is missing.
            let err = m.collect_final_results().unwrap_err();
            assert!(matches!(err, Error::ResultNotAvailable(JobId(1))));
        });
    }

    /// Helper: drain one sub mailbox into plain messages (flattening
    /// nothing — coalescing is off in these tests).
    fn drain(sub: &mut Comm<FwMsg>) -> Vec<FwMsg> {
        let mut msgs = Vec::new();
        while let Some(env) = sub.try_recv().unwrap() {
            msgs.push(env.into_user());
        }
        msgs
    }

    #[test]
    fn losing_replica_completion_is_tolerated_and_released() {
        with_master_and_sub(|m, sub| {
            m.cfg.stragglers = true;
            let winner = Rank(sub.rank().0 + 100); // not a live sub
            let job = JobId(1);
            m.specs.insert(job, JobSpec::new(1, 5, 1));
            m.assign(job); // goes to the real sub (the eventual loser)
            drain(sub);
            // The "winner" (a fake rank the test speaks for) finishes
            // first: completion settles the replica set — the loser gets a
            // ReleaseResult for its still-queued copy.
            m.handle_dataflow_event(
                winner,
                FwMsg::JobDone {
                    job,
                    kept_on: None,
                    chunks: 1,
                    injections: Vec::new(),
                    output_bytes: 0,
                    exec_us: 10,
                },
            )
            .unwrap();
            assert!(m.available.contains(&job));
            assert!(!m.pending.contains(&job));
            assert_eq!(m.owners.get(&job).map(|l| l.owner), Some(winner));
            // The loser's late completion is swallowed, and its copy is
            // released (a second ReleaseResult to the same sub is fine —
            // the release path is idempotent).
            m.handle_dataflow_event(
                sub.rank(),
                FwMsg::JobDone {
                    job,
                    kept_on: None,
                    chunks: 1,
                    injections: Vec::new(),
                    output_bytes: 0,
                    exec_us: 99,
                },
            )
            .unwrap();
            assert_eq!(m.owners.get(&job).map(|l| l.owner), Some(winner));
            let releases = drain(sub)
                .into_iter()
                .filter(|msg| matches!(msg, FwMsg::ReleaseResult { job: j } if *j == job))
                .count();
            assert_eq!(releases, 2, "settle + duplicate tolerance each release");
            // A stale abort from the loser is equally inert.
            m.handle_dataflow_event(
                sub.rank(),
                FwMsg::JobAborted { job, missing: JobId(9) },
            )
            .unwrap();
            assert!(m.available.contains(&job));
        });
    }

    #[test]
    fn straggler_deadline_dispatches_speculative_replica() {
        with_master_and_sub(|m, sub| {
            m.cfg.stragglers = true;
            m.cfg.straggler_cold_us = 1; // everything is overdue instantly
            m.cfg.straggler_factor = 1.0;
            m.cfg.job_retry_backoff_us = 0;
            let job = JobId(1);
            m.specs.insert(job, JobSpec::new(1, 5, 1));
            m.assign(job);
            assert_eq!(m.inflight.get(&job).map(|fl| fl.tries), Some(1));
            std::thread::sleep(Duration::from_millis(2));
            m.scan_stragglers().unwrap();
            let fl = m.inflight.get(&job).expect("still in flight");
            assert_eq!(fl.tries, 2, "one speculative replica dispatched");
            assert_eq!(fl.targets.len(), 2);
            let assigns = drain(sub)
                .into_iter()
                .filter(|msg| matches!(msg, FwMsg::Assign { .. }))
                .count();
            assert_eq!(assigns, 2, "original + replica Assign on the wire");
            // The first completion clears the in-flight entry entirely.
            m.handle_dataflow_event(
                sub.rank(),
                FwMsg::JobDone {
                    job,
                    kept_on: None,
                    chunks: 1,
                    injections: Vec::new(),
                    output_bytes: 0,
                    exec_us: 10,
                },
            )
            .unwrap();
            assert!(m.inflight.is_empty());
            drain(sub);
        });
    }

    #[test]
    fn rank_loss_within_budget_requeues_pending_work() {
        with_master_and_sub(|m, sub| {
            m.cfg.mode = ExecutionMode::Barrier;
            // A second (fake) sub that will die: jobs assigned there must
            // come back to the survivor.
            let doomed = Rank(sub.rank().0 + 100);
            m.cfg.subs.push(doomed);
            let job = JobId(1);
            m.specs.insert(job, JobSpec::new(1, 5, 1));
            // Pin the assignment onto the doomed rank by loading the
            // survivor heavily.
            m.load.insert(sub.rank(), 1000);
            m.assign(job);
            assert_eq!(m.owners.get(&job).map(|l| l.owner), Some(doomed));
            m.load.insert(sub.rank(), 0);
            m.on_rank_lost(doomed).unwrap();
            assert_eq!(m.lost_ranks, vec![doomed]);
            assert!(!m.cfg.subs.contains(&doomed));
            // The pending job was forgotten and re-assigned — necessarily
            // to the only survivor.
            assert_eq!(m.owners.get(&job).map(|l| l.owner), Some(sub.rank()));
            assert!(m.pending.contains(&job));
            // Losing the same rank twice is a tolerated no-op.
            m.on_rank_lost(doomed).unwrap();
            assert_eq!(m.lost_ranks.len(), 1);
            drain(sub);
        });
    }

    #[test]
    fn rank_loss_beyond_budget_degrades_with_a_report() {
        with_master(|m| {
            m.cfg.max_rank_losses = 0;
            let victim = m.cfg.subs[0];
            m.pending.insert(JobId(3));
            let err = m.on_rank_lost(victim).unwrap_err();
            match err {
                Error::Degraded(report) => {
                    assert_eq!(report.ranks_lost, vec![victim]);
                    assert_eq!(report.completed_jobs, 0);
                    assert_eq!(report.outstanding_jobs, vec![JobId(3)]);
                }
                other => panic!("expected Degraded, got {other}"),
            }
        });
    }
}
