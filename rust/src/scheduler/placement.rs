//! Placement policies: which scheduler gets a job, which worker runs it.
//!
//! Two levels, mirroring the paper:
//!
//! * **Master level** ([`choose_scheduler`]): data-locality first — a job
//!   consuming kept results *must* land on the scheduler whose worker
//!   retains them; otherwise prefer the scheduler owning the most of the
//!   job's referenced results; tie-break on least load.
//! * **Sub-scheduler level** ([`choose_worker`]): kept-locality first,
//!   then **thread-count bin packing** (paper §3.3: two 2-thread jobs
//!   share one 4-core worker) — best-fit on free cores; spawn a new
//!   worker only when nothing fits.

use std::collections::HashMap;

use super::SourceLoc;
use crate::comm::Rank;
use crate::job::{JobSpec, ThreadCount};

/// Below this many bytes of owned input, data affinity is ignored in
/// favour of load balancing (shipping a few KB is cheaper than idling a
/// scheduler's worker pool).
pub const AFFINITY_MIN_BYTES: u64 = 4096;

/// Master-side choice among sub-schedulers.
///
/// * `owners`: where each referenced result lives.
/// * `result_bytes`: known size of each result (0 = unknown/kept).
/// * `load`: outstanding (assigned, not done) jobs per scheduler.
pub fn choose_scheduler(
    spec: &JobSpec,
    owners: &HashMap<crate::job::JobId, SourceLoc>,
    result_bytes: &HashMap<crate::job::JobId, u64>,
    load: &HashMap<Rank, usize>,
    subs: &[Rank],
) -> Rank {
    choose_scheduler_lookahead(spec, &[], owners, result_bytes, load, &HashMap::new(), subs)
}

/// Weight of a successor's input bytes relative to the job's own inputs
/// in look-ahead packing (divisor: successors are one hop away, and their
/// remaining inputs may come from elsewhere).
const LOOKAHEAD_DISCOUNT: u64 = 2;

/// [`choose_scheduler`] with dataflow look-ahead: besides the job's own
/// inputs, weigh where its known *successors'* other inputs live (at half
/// weight), so a chain of ready jobs packs onto the sub-scheduler that
/// already owns the chain's data instead of ping-ponging between peers.
///
/// `est_load` is the cost model's estimated outstanding execution
/// microseconds per scheduler (DESIGN.md §9): when populated, the final
/// least-loaded tie-break prefers the scheduler with the least estimated
/// *cost* in flight, falling back to queue length only among equals — so
/// two queued one-job schedulers stop looking identical when one of the
/// jobs is a known hundred-millisecond kind.  Pass an empty map to
/// reproduce the pure queue-length policy (`cost_model = off`, or a cold
/// table charging zero everywhere).
///
/// Doubles as the **speculative-prefetch target predictor** (DESIGN.md
/// §7): the master evaluates it early — while a job still waits on its
/// last input — so the hinted scheduler and the eventual assignment
/// target coincide whenever the intervening completions don't shift the
/// byte-affinity balance.
pub fn choose_scheduler_lookahead(
    spec: &JobSpec,
    successors: &[JobSpec],
    owners: &HashMap<crate::job::JobId, SourceLoc>,
    result_bytes: &HashMap<crate::job::JobId, u64>,
    load: &HashMap<Rank, usize>,
    est_load: &HashMap<Rank, u64>,
    subs: &[Rank],
) -> Rank {
    debug_assert!(!subs.is_empty());

    // 1. Hard affinity: kept inputs pin the job to the retaining scheduler
    //    (its worker holds the data; running anywhere else forces a pull).
    for r in &spec.inputs {
        if let Some(loc) = owners.get(&r.job) {
            if loc.kept_on.is_some() {
                return loc.owner;
            }
        }
    }

    // 2. Soft affinity: the scheduler owning the most input *bytes* —
    //    but only when the data is heavy enough to matter.  Successor
    //    inputs (minus the job's own pending output, whose location is
    //    this very decision) count at a discount.
    let mut bytes: HashMap<Rank, u64> = HashMap::new();
    for r in &spec.inputs {
        if let Some(loc) = owners.get(&r.job) {
            let sz = result_bytes.get(&r.job).copied().unwrap_or(1);
            *bytes.entry(loc.owner).or_default() += sz.max(1);
        }
    }
    for succ in successors {
        for r in &succ.inputs {
            if r.job == spec.id {
                continue; // produced by the job being placed
            }
            if let Some(loc) = owners.get(&r.job) {
                let sz = result_bytes.get(&r.job).copied().unwrap_or(1);
                *bytes.entry(loc.owner).or_default() += sz.max(1) / LOOKAHEAD_DISCOUNT;
            }
        }
    }
    if let Some((&best, &sz)) = bytes.iter().max_by_key(|(s, b)| (**b, u32::MAX - s.0)) {
        if sz >= AFFINITY_MIN_BYTES {
            return best;
        }
    }

    // 3. Least loaded — by estimated outstanding cost first (zero when the
    //    cost model is off or cold, degrading to the original queue-length
    //    policy), then queue length, then lowest rank for determinism.
    subs.iter()
        .copied()
        .min_by_key(|s| {
            (
                est_load.get(s).copied().unwrap_or(0),
                load.get(s).copied().unwrap_or(0),
                s.0,
            )
        })
        .expect("subs non-empty")
}

/// One worker's packing state as seen by its sub-scheduler.
#[derive(Debug, Clone)]
pub struct WorkerSlot {
    /// The worker's rank.
    pub rank: Rank,
    /// Total cores of the worker node.
    pub cores: usize,
    /// Cores not currently occupied by running jobs.
    pub free_cores: usize,
    /// Jobs currently executing.
    pub running: usize,
}

impl WorkerSlot {
    /// Fresh idle slot for a worker with `cores` cores.
    pub fn new(rank: Rank, cores: usize) -> Self {
        WorkerSlot { rank, cores, free_cores: cores, running: 0 }
    }

    /// Whether a job with this thread request fits right now.
    pub fn fits(&self, threads: ThreadCount) -> bool {
        threads.packing_width(self.cores) <= self.free_cores
    }

    /// Account a job starting (claims its packing width).
    pub fn occupy(&mut self, threads: ThreadCount) {
        self.free_cores -= threads.packing_width(self.cores);
        self.running += 1;
    }

    /// Account a job finishing (returns its packing width).
    pub fn vacate(&mut self, threads: ThreadCount) {
        self.free_cores =
            (self.free_cores + threads.packing_width(self.cores)).min(self.cores);
        self.running -= 1;
    }
}

/// Sub-scheduler-side choice among its workers.
///
/// Returns the chosen worker rank, or `None` → caller should spawn a new
/// worker (if under budget) or queue the job.
///
/// Policy:
/// 1. If the job has kept inputs on `kept_on`, it must run there; return
///    it when the packing budget allows, else `None` with `must_wait`
///    semantics (caller queues — correctness over throughput).
/// 2. Otherwise **best-fit**: the worker with the smallest free-core
///    surplus that still fits (keeps big slots open for wide jobs).
pub fn choose_worker(
    spec: &JobSpec,
    kept_on: Option<Rank>,
    workers: &[WorkerSlot],
) -> WorkerChoice {
    if let Some(pin) = kept_on {
        return match workers.iter().find(|w| w.rank == pin) {
            Some(w) if w.fits(spec.threads) => WorkerChoice::Run(pin),
            Some(_) => WorkerChoice::WaitFor(pin),
            // Retaining worker is gone — the scheduler escalates (fault path).
            None => WorkerChoice::Lost(pin),
        };
    }
    let fit = workers
        .iter()
        .filter(|w| w.fits(spec.threads))
        .min_by_key(|w| {
            (
                w.free_cores - spec.threads.packing_width(w.cores), // best fit
                w.rank.0,                                           // determinism
            )
        });
    match fit {
        Some(w) => WorkerChoice::Run(w.rank),
        None => WorkerChoice::Spawn,
    }
}

/// Outcome of [`choose_worker`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkerChoice {
    /// Dispatch to this worker now.
    Run(Rank),
    /// Must run on this (kept-affinity) worker; wait for capacity.
    WaitFor(Rank),
    /// Kept-affinity worker no longer exists (crashed) — escalate.
    Lost(Rank),
    /// Nothing fits: spawn a new worker or queue.
    Spawn,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::{ChunkRef, JobId};

    fn subs() -> Vec<Rank> {
        vec![Rank(1), Rank(2)]
    }

    #[test]
    fn kept_input_pins_scheduler() {
        let spec = JobSpec::new(10, 1, 1)
            .with_inputs(vec![ChunkRef::all(JobId(1))]);
        let mut owners = HashMap::new();
        owners.insert(
            JobId(1),
            SourceLoc { job: JobId(1), owner: Rank(2), kept_on: Some(Rank(7)) },
        );
        let load = HashMap::new();
        let bytes = HashMap::new();
        assert_eq!(
            choose_scheduler(&spec, &owners, &bytes, &load, &subs()),
            Rank(2)
        );
    }

    #[test]
    fn heavy_affinity_beats_load() {
        let spec = JobSpec::new(10, 1, 1)
            .with_inputs(vec![ChunkRef::all(JobId(1)), ChunkRef::all(JobId(2))]);
        let mut owners = HashMap::new();
        let mut bytes = HashMap::new();
        for j in [1, 2] {
            owners.insert(
                JobId(j),
                SourceLoc { job: JobId(j), owner: Rank(2), kept_on: None },
            );
            bytes.insert(JobId(j), 1 << 20); // 1 MiB each
        }
        let mut load = HashMap::new();
        load.insert(Rank(2), 10); // busier but owns the data
        assert_eq!(
            choose_scheduler(&spec, &owners, &bytes, &load, &subs()),
            Rank(2)
        );
    }

    #[test]
    fn light_affinity_yields_to_load_balancing() {
        // A few bytes of owned input must not glue every job to one
        // scheduler (the Jacobi distribute jobs' 4-byte param chunks).
        let spec = JobSpec::new(10, 1, 1)
            .with_inputs(vec![ChunkRef::all(JobId(1))]);
        let mut owners = HashMap::new();
        owners.insert(
            JobId(1),
            SourceLoc { job: JobId(1), owner: Rank(2), kept_on: None },
        );
        let mut bytes = HashMap::new();
        bytes.insert(JobId(1), 16);
        let mut load = HashMap::new();
        load.insert(Rank(1), 0);
        load.insert(Rank(2), 3);
        assert_eq!(
            choose_scheduler(&spec, &owners, &bytes, &load, &subs()),
            Rank(1)
        );
    }

    #[test]
    fn no_affinity_goes_least_loaded() {
        let spec = JobSpec::new(10, 1, 1);
        let owners = HashMap::new();
        let bytes = HashMap::new();
        let mut load = HashMap::new();
        load.insert(Rank(1), 3);
        load.insert(Rank(2), 1);
        assert_eq!(
            choose_scheduler(&spec, &owners, &bytes, &load, &subs()),
            Rank(2)
        );
    }

    #[test]
    fn lookahead_packs_chain_onto_data_owner() {
        // J10's own input is light (would fall through to load balancing),
        // but its successor J11 consumes a heavy result owned by Rank(2):
        // look-ahead placement sends J10 there so the chain stays local.
        let spec = JobSpec::new(10, 1, 1)
            .with_inputs(vec![ChunkRef::all(JobId(1))]);
        let succ = JobSpec::new(11, 1, 1)
            .with_inputs(vec![ChunkRef::all(JobId(10)), ChunkRef::all(JobId(2))]);
        let mut owners = HashMap::new();
        let mut bytes = HashMap::new();
        owners.insert(
            JobId(1),
            SourceLoc { job: JobId(1), owner: Rank(1), kept_on: None },
        );
        bytes.insert(JobId(1), 16);
        owners.insert(
            JobId(2),
            SourceLoc { job: JobId(2), owner: Rank(2), kept_on: None },
        );
        bytes.insert(JobId(2), 1 << 20);
        let mut load = HashMap::new();
        load.insert(Rank(1), 0);
        load.insert(Rank(2), 3);
        // Without look-ahead: light affinity, least-loaded Rank(1) wins.
        assert_eq!(
            choose_scheduler(&spec, &owners, &bytes, &load, &subs()),
            Rank(1)
        );
        // With look-ahead: the successor's heavy input pulls it to Rank(2).
        assert_eq!(
            choose_scheduler_lookahead(
                &spec,
                std::slice::from_ref(&succ),
                &owners,
                &bytes,
                &load,
                &HashMap::new(),
                &subs()
            ),
            Rank(2)
        );
    }

    #[test]
    fn lookahead_ignores_own_pending_output() {
        // The successor's reference to the job being placed must not count
        // (its location IS the decision being made).
        let spec = JobSpec::new(10, 1, 1);
        let succ = JobSpec::new(11, 1, 1)
            .with_inputs(vec![ChunkRef::all(JobId(10))]);
        let owners = HashMap::new();
        let bytes = HashMap::new();
        let mut load = HashMap::new();
        load.insert(Rank(1), 1);
        load.insert(Rank(2), 0);
        assert_eq!(
            choose_scheduler_lookahead(
                &spec,
                std::slice::from_ref(&succ),
                &owners,
                &bytes,
                &load,
                &HashMap::new(),
                &subs()
            ),
            Rank(2)
        );
    }

    #[test]
    fn estimated_cost_breaks_queue_length_ties() {
        // Both schedulers hold one outstanding job, but Rank(1)'s is a
        // known-expensive kind: the cost model sends the new job to
        // Rank(2) even though plain queue length (and rank order) would
        // pick Rank(1).
        let spec = JobSpec::new(10, 1, 1);
        let owners = HashMap::new();
        let bytes = HashMap::new();
        let mut load = HashMap::new();
        load.insert(Rank(1), 1);
        load.insert(Rank(2), 1);
        let mut est = HashMap::new();
        est.insert(Rank(1), 100_000u64); // 100 ms estimated outstanding
        est.insert(Rank(2), 2_000u64);
        assert_eq!(
            choose_scheduler_lookahead(&spec, &[], &owners, &bytes, &load, &est, &subs()),
            Rank(2)
        );
        // Empty estimates reproduce the queue-length policy exactly.
        assert_eq!(
            choose_scheduler_lookahead(
                &spec,
                &[],
                &owners,
                &bytes,
                &load,
                &HashMap::new(),
                &subs()
            ),
            Rank(1)
        );
    }

    #[test]
    fn packing_two_2thread_jobs_on_4core_worker() {
        // The paper's J3/J4 example.
        let mut w = WorkerSlot::new(Rank(5), 4);
        let j3 = JobSpec::new(3, 2, 2);
        let j4 = JobSpec::new(4, 2, 2);
        assert_eq!(choose_worker(&j3, None, &[w.clone()]), WorkerChoice::Run(Rank(5)));
        w.occupy(j3.threads);
        assert_eq!(choose_worker(&j4, None, &[w.clone()]), WorkerChoice::Run(Rank(5)));
        w.occupy(j4.threads);
        // Third 2-thread job no longer fits.
        let j5 = JobSpec::new(5, 2, 2);
        assert_eq!(choose_worker(&j5, None, &[w.clone()]), WorkerChoice::Spawn);
        w.vacate(j3.threads);
        assert_eq!(choose_worker(&j5, None, &[w]), WorkerChoice::Run(Rank(5)));
    }

    #[test]
    fn auto_threads_take_whole_node() {
        let w = WorkerSlot::new(Rank(5), 4);
        let auto = JobSpec::new(1, 1, 0); // ThreadCount::Auto
        let mut w2 = w.clone();
        w2.occupy(auto.threads);
        assert_eq!(w2.free_cores, 0);
        let one = JobSpec::new(2, 1, 1);
        assert_eq!(choose_worker(&one, None, &[w2]), WorkerChoice::Spawn);
    }

    #[test]
    fn best_fit_prefers_tightest_slot() {
        let mut a = WorkerSlot::new(Rank(1), 4);
        a.occupy(ThreadCount::Exact(1)); // 3 free
        let mut b = WorkerSlot::new(Rank(2), 4);
        b.occupy(ThreadCount::Exact(2)); // 2 free
        let j = JobSpec::new(9, 1, 2);
        // Both fit; best-fit picks b (surplus 0 < surplus 1).
        assert_eq!(choose_worker(&j, None, &[a, b]), WorkerChoice::Run(Rank(2)));
    }

    #[test]
    fn kept_affinity_waits_or_escalates() {
        let mut w = WorkerSlot::new(Rank(3), 2);
        w.occupy(ThreadCount::Exact(2));
        let j = JobSpec::new(9, 1, 1);
        assert_eq!(
            choose_worker(&j, Some(Rank(3)), &[w]),
            WorkerChoice::WaitFor(Rank(3))
        );
        assert_eq!(
            choose_worker(&j, Some(Rank(9)), &[]),
            WorkerChoice::Lost(Rank(9))
        );
    }
}
