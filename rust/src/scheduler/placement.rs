//! Placement policies: which scheduler gets a job, which worker runs it.
//!
//! Two levels, mirroring the paper:
//!
//! * **Master level** ([`choose_scheduler`]): data-locality first — a job
//!   consuming kept results *must* land on the scheduler whose worker
//!   retains them; otherwise prefer the scheduler owning the most of the
//!   job's referenced results; tie-break on least load.
//! * **Sub-scheduler level** ([`choose_worker`]): kept-locality first,
//!   then **thread-count bin packing** (paper §3.3: two 2-thread jobs
//!   share one 4-core worker) — best-fit on free cores; spawn a new
//!   worker only when nothing fits.

use std::collections::HashMap;

use super::SourceLoc;
use crate::comm::{Rank, TransferEstimate};
use crate::job::{JobId, JobSpec, ThreadCount};

/// Below this many bytes of owned input, data affinity is ignored in
/// favour of load balancing (shipping a few KB is cheaper than idling a
/// scheduler's worker pool).
pub const AFFINITY_MIN_BYTES: u64 = 4096;

/// Master-side choice among sub-schedulers.
///
/// * `owners`: where each referenced result lives.
/// * `result_bytes`: known size of each result (0 = unknown/kept).
/// * `load`: outstanding (assigned, not done) jobs per scheduler.
pub fn choose_scheduler(
    spec: &JobSpec,
    owners: &HashMap<crate::job::JobId, SourceLoc>,
    result_bytes: &HashMap<crate::job::JobId, u64>,
    load: &HashMap<Rank, usize>,
    subs: &[Rank],
) -> Rank {
    choose_scheduler_lookahead(spec, &[], owners, result_bytes, load, &HashMap::new(), subs)
}

/// Weight of a successor's input bytes relative to the job's own inputs
/// in look-ahead packing (divisor: successors are one hop away, and their
/// remaining inputs may come from elsewhere).
const LOOKAHEAD_DISCOUNT: u64 = 2;

/// [`choose_scheduler`] with dataflow look-ahead: besides the job's own
/// inputs, weigh where its known *successors'* other inputs live (at half
/// weight), so a chain of ready jobs packs onto the sub-scheduler that
/// already owns the chain's data instead of ping-ponging between peers.
///
/// `est_load` is the cost model's estimated outstanding execution
/// microseconds per scheduler (DESIGN.md §9): when populated, the final
/// least-loaded tie-break prefers the scheduler with the least estimated
/// *cost* in flight, falling back to queue length only among equals — so
/// two queued one-job schedulers stop looking identical when one of the
/// jobs is a known hundred-millisecond kind.  Pass an empty map to
/// reproduce the pure queue-length policy (`cost_model = off`, or a cold
/// table charging zero everywhere).
///
/// Doubles as the **speculative-prefetch target predictor** (DESIGN.md
/// §7): the master evaluates it early — while a job still waits on its
/// last input — so the hinted scheduler and the eventual assignment
/// target coincide whenever the intervening completions don't shift the
/// byte-affinity balance.
pub fn choose_scheduler_lookahead(
    spec: &JobSpec,
    successors: &[JobSpec],
    owners: &HashMap<crate::job::JobId, SourceLoc>,
    result_bytes: &HashMap<crate::job::JobId, u64>,
    load: &HashMap<Rank, usize>,
    est_load: &HashMap<Rank, u64>,
    subs: &[Rank],
) -> Rank {
    debug_assert!(!subs.is_empty());

    // 1. Hard affinity: kept inputs pin the job to the retaining scheduler
    //    (its worker holds the data; running anywhere else forces a pull).
    for r in &spec.inputs {
        if let Some(loc) = owners.get(&r.job) {
            if loc.kept_on.is_some() {
                return loc.owner;
            }
        }
    }

    // 2. Soft affinity: the scheduler owning the most input *bytes* —
    //    but only when the data is heavy enough to matter.  Successor
    //    inputs (minus the job's own pending output, whose location is
    //    this very decision) count at a discount.
    let mut bytes: HashMap<Rank, u64> = HashMap::new();
    for r in &spec.inputs {
        if let Some(loc) = owners.get(&r.job) {
            let sz = result_bytes.get(&r.job).copied().unwrap_or(1);
            *bytes.entry(loc.owner).or_default() += sz.max(1);
        }
    }
    for succ in successors {
        for r in &succ.inputs {
            if r.job == spec.id {
                continue; // produced by the job being placed
            }
            if let Some(loc) = owners.get(&r.job) {
                let sz = result_bytes.get(&r.job).copied().unwrap_or(1);
                *bytes.entry(loc.owner).or_default() += sz.max(1) / LOOKAHEAD_DISCOUNT;
            }
        }
    }
    if let Some((&best, &sz)) = bytes.iter().max_by_key(|(s, b)| (**b, u32::MAX - s.0)) {
        if sz >= AFFINITY_MIN_BYTES {
            return best;
        }
    }

    // 3. Least loaded — by estimated outstanding cost first (zero when the
    //    cost model is off or cold, degrading to the original queue-length
    //    policy), then queue length, then lowest rank for determinism.
    subs.iter()
        .copied()
        .min_by_key(|s| {
            (
                est_load.get(s).copied().unwrap_or(0),
                load.get(s).copied().unwrap_or(0),
                s.0,
            )
        })
        .expect("subs non-empty")
}

/// Flat cost, µs, added to a near-budget sub's estimated outstanding
/// load by [`apply_memory_pressure`] (on top of doubling it), so
/// pressure outweighs ordinary tie-breaks even when the cost model is
/// cold and every `est_load` entry is zero.
const MEMORY_PRESSURE_PENALTY_US: u64 = 10_000;

/// Memory-pressure placement feedback (DESIGN.md §16): with a byte
/// budget in force, a sub whose tracked stored bytes reached 7/8 of the
/// budget gets its estimated outstanding cost doubled plus a flat
/// penalty, steering new work — and the result bytes it will store —
/// toward ranks with headroom.  Returns `None` when `budget == 0`
/// (knob unset): callers then pass their untouched `est_load` through,
/// keeping the unbounded placement inputs bit-for-bit identical.
pub fn apply_memory_pressure(
    est_load: &HashMap<Rank, u64>,
    stored_bytes: &HashMap<Rank, u64>,
    budget: u64,
) -> Option<HashMap<Rank, u64>> {
    if budget == 0 {
        return None;
    }
    let threshold = budget.saturating_sub(budget / 8);
    let mut out = est_load.clone();
    for (&rank, &bytes) in stored_bytes {
        if bytes >= threshold {
            let e = out.entry(rank).or_default();
            *e = e.saturating_mul(2).saturating_add(MEMORY_PRESSURE_PENALTY_US);
        }
    }
    Some(out)
}

/// Master-side placement entry point: comm-aware when a transfer model is
/// supplied (`comm_aware_placement = on`), the PR 4 byte-affinity policy
/// otherwise.  Keeping the off-path a literal call to
/// [`choose_scheduler_lookahead`] is what makes the knob's "off reproduces
/// the previous placement bit-for-bit" guarantee structural rather than
/// behavioural (pinned by `prop_comm_aware_off_is_pr4_placement`).
#[allow(clippy::too_many_arguments)]
pub fn choose_scheduler_policy(
    spec: &JobSpec,
    successors: &[JobSpec],
    owners: &HashMap<JobId, SourceLoc>,
    result_bytes: &HashMap<JobId, u64>,
    load: &HashMap<Rank, usize>,
    est_load: &HashMap<Rank, u64>,
    subs: &[Rank],
    comm: Option<&dyn TransferEstimate>,
) -> Rank {
    match comm {
        Some(model) => choose_scheduler_comm_aware(
            spec,
            successors,
            owners,
            result_bytes,
            load,
            est_load,
            subs,
            model,
        ),
        None => choose_scheduler_lookahead(
            spec,
            successors,
            owners,
            result_bytes,
            load,
            est_load,
            subs,
        ),
    }
}

/// Comm-aware master placement (DESIGN.md §10): minimise estimated
/// **compute + transfer** time end-to-end.  Each candidate sub-scheduler
/// is priced as
///
/// ```text
/// score(s) = est_outstanding_us(s) + queued(s) · α̂
///          + Σ_own    modelled_transfer_us(owner → s, bytes)
///          + Σ_succ   modelled_transfer_us(owner → s, bytes) / 2
/// ```
///
/// over the job's distinct inputs not already resident on `s` (and its
/// known successors' other inputs at the look-ahead discount), and the
/// cheapest candidate wins — exact ties break by queue length, then
/// lowest rank.  `α̂` is the queue-depth floor: the dearest one-byte
/// (≈ pure-latency) transfer price among the job's priced links.  With a
/// cold or disabled execution-cost model `est_outstanding_us` is zero for
/// everyone, and without the floor every consumer of a result would herd
/// onto its owner no matter how deep that sub's queue grew; pricing each
/// queued job at one message latency makes light inputs spill to idle
/// peers once the queue outweighs the move (the comm-aware analogue of
/// the old light-affinity load balancing) while a genuinely heavy
/// operand still outweighs any realistic queue.
///
/// This subsumes PR 4's threshold logic: heavy co-located data wins
/// because moving it is expensive, and light data yields to load
/// balancing because its transfer prices near α — without the hard
/// [`AFFINITY_MIN_BYTES`] cliff.  Kept inputs still pin the job to the
/// retaining scheduler (step 1, unchanged: the data physically lives in a
/// worker cache there).
#[allow(clippy::too_many_arguments)]
pub fn choose_scheduler_comm_aware(
    spec: &JobSpec,
    successors: &[JobSpec],
    owners: &HashMap<JobId, SourceLoc>,
    result_bytes: &HashMap<JobId, u64>,
    load: &HashMap<Rank, usize>,
    est_load: &HashMap<Rank, u64>,
    subs: &[Rank],
    comm: &dyn TransferEstimate,
) -> Rank {
    debug_assert!(!subs.is_empty());

    // 1. Hard affinity: kept inputs pin the job to the retaining scheduler.
    for r in &spec.inputs {
        if let Some(loc) = owners.get(&r.job) {
            if loc.kept_on.is_some() {
                return loc.owner;
            }
        }
    }

    // Distinct priced sources: the consuming sub fetches a referenced
    // result once however many ChunkRefs point at it.
    let mut own: HashMap<JobId, (Rank, u64)> = HashMap::new();
    for r in &spec.inputs {
        if let Some(loc) = owners.get(&r.job) {
            let sz = result_bytes.get(&r.job).copied().unwrap_or(1).max(1);
            own.entry(r.job).or_insert((loc.owner, sz));
        }
    }
    let mut succ: HashMap<JobId, (Rank, u64)> = HashMap::new();
    for s in successors {
        for r in &s.inputs {
            if r.job == spec.id || own.contains_key(&r.job) {
                continue; // our own output / already priced at full weight
            }
            if let Some(loc) = owners.get(&r.job) {
                let sz = result_bytes.get(&r.job).copied().unwrap_or(1).max(1);
                succ.entry(r.job).or_insert((loc.owner, sz));
            }
        }
    }

    // Queue-depth floor α̂: the dearest one-byte transfer among the
    // priced links — zero when the job has no priced inputs (score then
    // degrades to est_load with the queue/rank tie-breaks, as before).
    let mut alpha_hat = 0.0f64;
    for &s in subs {
        for &(owner, _) in own.values().chain(succ.values()) {
            alpha_hat = alpha_hat.max(comm.modelled_transfer_us(owner, s, 1));
        }
    }

    // 2. One unified score per candidate; deterministic tie-breaks.
    let mut best: Option<(f64, usize, Rank)> = None;
    for &s in subs {
        let queued = load.get(&s).copied().unwrap_or(0);
        let mut score =
            est_load.get(&s).copied().unwrap_or(0) as f64 + queued as f64 * alpha_hat;
        for &(owner, sz) in own.values() {
            score += comm.modelled_transfer_us(owner, s, sz);
        }
        for &(owner, sz) in succ.values() {
            score += comm.modelled_transfer_us(owner, s, sz) / LOOKAHEAD_DISCOUNT as f64;
        }
        let better = match best {
            None => true,
            Some((bs, bq, br)) => {
                score < bs || (score == bs && (queued, s.0) < (bq, br.0))
            }
        };
        if better {
            best = Some((score, queued, s));
        }
    }
    best.expect("subs non-empty").2
}

/// One worker's packing state as seen by its sub-scheduler.
#[derive(Debug, Clone)]
pub struct WorkerSlot {
    /// The worker's rank.
    pub rank: Rank,
    /// Total cores of the worker node.
    pub cores: usize,
    /// Cores not currently occupied by running jobs.
    pub free_cores: usize,
    /// Jobs currently executing.
    pub running: usize,
}

impl WorkerSlot {
    /// Fresh idle slot for a worker with `cores` cores.
    pub fn new(rank: Rank, cores: usize) -> Self {
        WorkerSlot { rank, cores, free_cores: cores, running: 0 }
    }

    /// Whether a job with this thread request fits right now.
    pub fn fits(&self, threads: ThreadCount) -> bool {
        threads.packing_width(self.cores) <= self.free_cores
    }

    /// Account a job starting (claims its packing width).
    pub fn occupy(&mut self, threads: ThreadCount) {
        self.free_cores -= threads.packing_width(self.cores);
        self.running += 1;
    }

    /// Account a job finishing (returns its packing width).
    pub fn vacate(&mut self, threads: ThreadCount) {
        self.free_cores =
            (self.free_cores + threads.packing_width(self.cores)).min(self.cores);
        self.running -= 1;
    }
}

/// Sub-scheduler-side choice among its workers.
///
/// Returns the chosen worker rank, or `None` → caller should spawn a new
/// worker (if under budget) or queue the job.
///
/// Policy:
/// 1. If the job has kept inputs on `kept_on`, it must run there; return
///    it when the packing budget allows, else `None` with `must_wait`
///    semantics (caller queues — correctness over throughput).
/// 2. Otherwise **best-fit**: the worker with the smallest free-core
///    surplus that still fits (keeps big slots open for wide jobs).
pub fn choose_worker(
    spec: &JobSpec,
    kept_on: Option<Rank>,
    workers: &[WorkerSlot],
) -> WorkerChoice {
    choose_worker_preferring(spec, kept_on, &[], workers)
}

/// [`choose_worker`] with a soft data-locality preference (kept-result
/// prefetch, DESIGN.md §10): among *fitting* workers, one holding a
/// pushed copy of the job's inputs in its cache beats a tighter best-fit
/// surplus — avoiding the input ship at dispatch is worth more than
/// packing tightness.  An empty `preferred` slice reproduces
/// [`choose_worker`] exactly, and the preference never overrides the hard
/// kept-affinity pin or the fits test (a busy preferred worker is simply
/// not chosen — the job runs elsewhere off the scheduler-store copy).
pub fn choose_worker_preferring(
    spec: &JobSpec,
    kept_on: Option<Rank>,
    preferred: &[Rank],
    workers: &[WorkerSlot],
) -> WorkerChoice {
    if let Some(pin) = kept_on {
        return match workers.iter().find(|w| w.rank == pin) {
            Some(w) if w.fits(spec.threads) => WorkerChoice::Run(pin),
            Some(_) => WorkerChoice::WaitFor(pin),
            // Retaining worker is gone — the scheduler escalates (fault path).
            None => WorkerChoice::Lost(pin),
        };
    }
    match best_fit(spec.threads, preferred, workers) {
        Some(rank) => WorkerChoice::Run(rank),
        None => WorkerChoice::Spawn,
    }
}

/// The §3.3 best-fit packing rule as a bare selector: among the workers
/// that fit `threads`, pick (preferred first, tightest surplus, lowest
/// rank); `None` when nothing fits.  One definition shared by dispatch
/// ([`choose_worker_preferring`]) and the kept-prefetch worker predictor
/// (DESIGN.md §10), so the prediction cannot drift from the dispatch
/// policy.
pub fn best_fit(
    threads: ThreadCount,
    preferred: &[Rank],
    workers: &[WorkerSlot],
) -> Option<Rank> {
    workers
        .iter()
        .filter(|w| w.fits(threads))
        .min_by_key(|w| {
            (
                !preferred.contains(&w.rank),              // warm cache first
                w.free_cores - threads.packing_width(w.cores), // best fit
                w.rank.0,                                  // determinism
            )
        })
        .map(|w| w.rank)
}

/// Bulk LPT ordering for an amortised assignment pass (DESIGN.md §12).
///
/// When control-plane batching lets the master drain a whole mailbox of
/// completions before scheduling, the ready frontier it then assigns is
/// *many* jobs, not one — and greedy least-loaded placement is famously
/// order-sensitive.  Longest-Processing-Time-first fixes the worst case:
/// sort the frontier by estimated cost descending before running the
/// existing sequential greedy (which charges `est_load` per placement),
/// so the big rocks land first and the pebbles fill the gaps.  Cold
/// estimates (all zeros) sort by `JobId` ascending, reproducing the
/// plain ready-queue order, and the caller skips this entirely when the
/// `ctrl_batching` knob is off or the frontier is a single job — keeping
/// the off-knob path the PR 5 order bit-for-bit.
pub fn bulk_assign_order(mut jobs: Vec<(JobId, u64)>) -> Vec<(JobId, u64)> {
    jobs.sort_by(|a, b| b.1.cmp(&a.1).then(a.0 .0.cmp(&b.0 .0)));
    jobs
}

/// Outcome of [`choose_worker`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkerChoice {
    /// Dispatch to this worker now.
    Run(Rank),
    /// Must run on this (kept-affinity) worker; wait for capacity.
    WaitFor(Rank),
    /// Kept-affinity worker no longer exists (crashed) — escalate.
    Lost(Rank),
    /// Nothing fits: spawn a new worker or queue.
    Spawn,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::{ChunkRef, JobId};

    fn subs() -> Vec<Rank> {
        vec![Rank(1), Rank(2)]
    }

    #[test]
    fn memory_pressure_off_when_budget_unset() {
        let mut est = HashMap::new();
        est.insert(Rank(1), 5);
        let mut stored = HashMap::new();
        stored.insert(Rank(1), u64::MAX);
        assert!(apply_memory_pressure(&est, &stored, 0).is_none());
    }

    #[test]
    fn memory_pressure_penalises_only_near_budget_ranks() {
        let budget = 1000u64;
        let mut est = HashMap::new();
        est.insert(Rank(1), 40);
        est.insert(Rank(2), 40);
        let mut stored = HashMap::new();
        stored.insert(Rank(1), 900); // ≥ 7/8 of budget: pressured
        stored.insert(Rank(2), 500); // headroom: untouched
        let out = apply_memory_pressure(&est, &stored, budget).unwrap();
        assert_eq!(out.get(&Rank(1)).copied(), Some(80 + 10_000));
        assert_eq!(out.get(&Rank(2)).copied(), Some(40));
    }

    #[test]
    fn memory_pressure_steers_placement_away_from_full_rank() {
        // Cold cost model (zero est_load everywhere): the flat penalty
        // alone must flip the least-loaded tie-break off the full rank.
        let spec = JobSpec::new(10, 1, 1);
        let owners = HashMap::new();
        let bytes = HashMap::new();
        let load = HashMap::new();
        let est = HashMap::new();
        let mut stored = HashMap::new();
        stored.insert(Rank(1), 1000);
        let pressured = apply_memory_pressure(&est, &stored, 1000).unwrap();
        let target = choose_scheduler_policy(
            &spec, &[], &owners, &bytes, &load, &pressured, &subs(), None,
        );
        assert_eq!(target, Rank(2));
    }

    #[test]
    fn kept_input_pins_scheduler() {
        let spec = JobSpec::new(10, 1, 1)
            .with_inputs(vec![ChunkRef::all(JobId(1))]);
        let mut owners = HashMap::new();
        owners.insert(
            JobId(1),
            SourceLoc { job: JobId(1), owner: Rank(2), kept_on: Some(Rank(7)) },
        );
        let load = HashMap::new();
        let bytes = HashMap::new();
        assert_eq!(
            choose_scheduler(&spec, &owners, &bytes, &load, &subs()),
            Rank(2)
        );
    }

    #[test]
    fn heavy_affinity_beats_load() {
        let spec = JobSpec::new(10, 1, 1)
            .with_inputs(vec![ChunkRef::all(JobId(1)), ChunkRef::all(JobId(2))]);
        let mut owners = HashMap::new();
        let mut bytes = HashMap::new();
        for j in [1, 2] {
            owners.insert(
                JobId(j),
                SourceLoc { job: JobId(j), owner: Rank(2), kept_on: None },
            );
            bytes.insert(JobId(j), 1 << 20); // 1 MiB each
        }
        let mut load = HashMap::new();
        load.insert(Rank(2), 10); // busier but owns the data
        assert_eq!(
            choose_scheduler(&spec, &owners, &bytes, &load, &subs()),
            Rank(2)
        );
    }

    #[test]
    fn light_affinity_yields_to_load_balancing() {
        // A few bytes of owned input must not glue every job to one
        // scheduler (the Jacobi distribute jobs' 4-byte param chunks).
        let spec = JobSpec::new(10, 1, 1)
            .with_inputs(vec![ChunkRef::all(JobId(1))]);
        let mut owners = HashMap::new();
        owners.insert(
            JobId(1),
            SourceLoc { job: JobId(1), owner: Rank(2), kept_on: None },
        );
        let mut bytes = HashMap::new();
        bytes.insert(JobId(1), 16);
        let mut load = HashMap::new();
        load.insert(Rank(1), 0);
        load.insert(Rank(2), 3);
        assert_eq!(
            choose_scheduler(&spec, &owners, &bytes, &load, &subs()),
            Rank(1)
        );
    }

    #[test]
    fn no_affinity_goes_least_loaded() {
        let spec = JobSpec::new(10, 1, 1);
        let owners = HashMap::new();
        let bytes = HashMap::new();
        let mut load = HashMap::new();
        load.insert(Rank(1), 3);
        load.insert(Rank(2), 1);
        assert_eq!(
            choose_scheduler(&spec, &owners, &bytes, &load, &subs()),
            Rank(2)
        );
    }

    #[test]
    fn lookahead_packs_chain_onto_data_owner() {
        // J10's own input is light (would fall through to load balancing),
        // but its successor J11 consumes a heavy result owned by Rank(2):
        // look-ahead placement sends J10 there so the chain stays local.
        let spec = JobSpec::new(10, 1, 1)
            .with_inputs(vec![ChunkRef::all(JobId(1))]);
        let succ = JobSpec::new(11, 1, 1)
            .with_inputs(vec![ChunkRef::all(JobId(10)), ChunkRef::all(JobId(2))]);
        let mut owners = HashMap::new();
        let mut bytes = HashMap::new();
        owners.insert(
            JobId(1),
            SourceLoc { job: JobId(1), owner: Rank(1), kept_on: None },
        );
        bytes.insert(JobId(1), 16);
        owners.insert(
            JobId(2),
            SourceLoc { job: JobId(2), owner: Rank(2), kept_on: None },
        );
        bytes.insert(JobId(2), 1 << 20);
        let mut load = HashMap::new();
        load.insert(Rank(1), 0);
        load.insert(Rank(2), 3);
        // Without look-ahead: light affinity, least-loaded Rank(1) wins.
        assert_eq!(
            choose_scheduler(&spec, &owners, &bytes, &load, &subs()),
            Rank(1)
        );
        // With look-ahead: the successor's heavy input pulls it to Rank(2).
        assert_eq!(
            choose_scheduler_lookahead(
                &spec,
                std::slice::from_ref(&succ),
                &owners,
                &bytes,
                &load,
                &HashMap::new(),
                &subs()
            ),
            Rank(2)
        );
    }

    #[test]
    fn lookahead_ignores_own_pending_output() {
        // The successor's reference to the job being placed must not count
        // (its location IS the decision being made).
        let spec = JobSpec::new(10, 1, 1);
        let succ = JobSpec::new(11, 1, 1)
            .with_inputs(vec![ChunkRef::all(JobId(10))]);
        let owners = HashMap::new();
        let bytes = HashMap::new();
        let mut load = HashMap::new();
        load.insert(Rank(1), 1);
        load.insert(Rank(2), 0);
        assert_eq!(
            choose_scheduler_lookahead(
                &spec,
                std::slice::from_ref(&succ),
                &owners,
                &bytes,
                &load,
                &HashMap::new(),
                &subs()
            ),
            Rank(2)
        );
    }

    #[test]
    fn estimated_cost_breaks_queue_length_ties() {
        // Both schedulers hold one outstanding job, but Rank(1)'s is a
        // known-expensive kind: the cost model sends the new job to
        // Rank(2) even though plain queue length (and rank order) would
        // pick Rank(1).
        let spec = JobSpec::new(10, 1, 1);
        let owners = HashMap::new();
        let bytes = HashMap::new();
        let mut load = HashMap::new();
        load.insert(Rank(1), 1);
        load.insert(Rank(2), 1);
        let mut est = HashMap::new();
        est.insert(Rank(1), 100_000u64); // 100 ms estimated outstanding
        est.insert(Rank(2), 2_000u64);
        assert_eq!(
            choose_scheduler_lookahead(&spec, &[], &owners, &bytes, &load, &est, &subs()),
            Rank(2)
        );
        // Empty estimates reproduce the queue-length policy exactly.
        assert_eq!(
            choose_scheduler_lookahead(
                &spec,
                &[],
                &owners,
                &bytes,
                &load,
                &HashMap::new(),
                &subs()
            ),
            Rank(1)
        );
    }

    /// Fixed uniform α/β estimator for placement tests.
    struct FlatLink {
        alpha_us: f64,
        us_per_byte: f64,
    }

    impl TransferEstimate for FlatLink {
        fn modelled_transfer_us(&self, from: Rank, to: Rank, bytes: u64) -> f64 {
            if from == to || bytes == 0 {
                0.0
            } else {
                self.alpha_us + self.us_per_byte * bytes as f64
            }
        }
    }

    #[test]
    fn comm_aware_prices_sub_threshold_data_instead_of_ignoring_it() {
        // 2000 bytes on Rank(2): below AFFINITY_MIN_BYTES, so the PR 4
        // policy ignores it and load-balances to Rank(1) — the comm-aware
        // score keeps the job with its data because moving 2000 bytes
        // costs 2 ms on this link and nothing is queued anywhere.
        let spec = JobSpec::new(10, 1, 1).with_inputs(vec![ChunkRef::all(JobId(1))]);
        let mut owners = HashMap::new();
        owners.insert(
            JobId(1),
            SourceLoc { job: JobId(1), owner: Rank(2), kept_on: None },
        );
        let mut bytes = HashMap::new();
        bytes.insert(JobId(1), 2000);
        let load = HashMap::new();
        let link = FlatLink { alpha_us: 20.0, us_per_byte: 1.0 };
        assert_eq!(
            choose_scheduler_lookahead(
                &spec,
                &[],
                &owners,
                &bytes,
                &load,
                &HashMap::new(),
                &subs()
            ),
            Rank(1),
            "PR 4 treats sub-threshold bytes as no affinity"
        );
        assert_eq!(
            choose_scheduler_comm_aware(
                &spec,
                &[],
                &owners,
                &bytes,
                &load,
                &HashMap::new(),
                &subs(),
                &link
            ),
            Rank(2),
            "comm-aware placement prices the transfer and stays resident"
        );
    }

    #[test]
    fn comm_aware_trades_transfer_against_estimated_backlog() {
        // The data owner Rank(2) has 10 ms of estimated outstanding work;
        // shipping the 2000-byte input costs ~2 ms — moving wins.  Shrink
        // the backlog below the transfer price and staying wins again.
        let spec = JobSpec::new(10, 1, 1).with_inputs(vec![ChunkRef::all(JobId(1))]);
        let mut owners = HashMap::new();
        owners.insert(
            JobId(1),
            SourceLoc { job: JobId(1), owner: Rank(2), kept_on: None },
        );
        let mut bytes = HashMap::new();
        bytes.insert(JobId(1), 2000);
        let load = HashMap::new();
        let link = FlatLink { alpha_us: 20.0, us_per_byte: 1.0 };
        let mut est = HashMap::new();
        est.insert(Rank(2), 10_000u64);
        assert_eq!(
            choose_scheduler_comm_aware(
                &spec, &[], &owners, &bytes, &load, &est, &subs(), &link
            ),
            Rank(1),
            "2 ms transfer beats 10 ms backlog"
        );
        est.insert(Rank(2), 500u64);
        assert_eq!(
            choose_scheduler_comm_aware(
                &spec, &[], &owners, &bytes, &load, &est, &subs(), &link
            ),
            Rank(2),
            "0.5 ms backlog beats 2 ms transfer"
        );
    }

    #[test]
    fn comm_aware_cold_model_spills_off_a_deep_queue() {
        // With the execution-cost model cold or off (est_load empty), the
        // queue-depth floor must keep the policy from herding every
        // consumer onto the data owner: once the owner's queue outweighs
        // the move price (queued · α̂ > transfer), the job spills to the
        // idle peer.  Here the 2000-byte move costs 2020 µs and α̂ is
        // 21 µs, so ~100 queued jobs tip the balance.
        let spec = JobSpec::new(10, 1, 1).with_inputs(vec![ChunkRef::all(JobId(1))]);
        let mut owners = HashMap::new();
        owners.insert(
            JobId(1),
            SourceLoc { job: JobId(1), owner: Rank(2), kept_on: None },
        );
        let mut bytes = HashMap::new();
        bytes.insert(JobId(1), 2000);
        let link = FlatLink { alpha_us: 20.0, us_per_byte: 1.0 };
        let mut load = HashMap::new();
        load.insert(Rank(2), 10);
        assert_eq!(
            choose_scheduler_comm_aware(
                &spec,
                &[],
                &owners,
                &bytes,
                &load,
                &HashMap::new(),
                &subs(),
                &link
            ),
            Rank(2),
            "shallow queue: staying with the data still wins"
        );
        load.insert(Rank(2), 200);
        assert_eq!(
            choose_scheduler_comm_aware(
                &spec,
                &[],
                &owners,
                &bytes,
                &load,
                &HashMap::new(),
                &subs(),
                &link
            ),
            Rank(1),
            "deep queue: the floor spills the job to the idle peer"
        );
    }

    #[test]
    fn comm_aware_keeps_kept_pin_and_dedupes_refs() {
        // Kept inputs pin regardless of any pricing...
        let spec = JobSpec::new(10, 1, 1).with_inputs(vec![ChunkRef::all(JobId(1))]);
        let mut owners = HashMap::new();
        owners.insert(
            JobId(1),
            SourceLoc { job: JobId(1), owner: Rank(2), kept_on: Some(Rank(9)) },
        );
        let link = FlatLink { alpha_us: 1.0, us_per_byte: 1.0 };
        let mut est = HashMap::new();
        est.insert(Rank(2), u64::MAX / 2);
        assert_eq!(
            choose_scheduler_comm_aware(
                &spec,
                &[],
                &owners,
                &HashMap::new(),
                &HashMap::new(),
                &est,
                &subs(),
                &link
            ),
            Rank(2)
        );
        // ...and two ChunkRefs to one producer price one fetch, not two:
        // J10 slices J1 (3000 B, on Rank 2) twice; J2 owns 5000 B on
        // Rank(1).  Deduped: moving to Rank(1) ships 3000, to Rank(2)
        // ships 5000 → Rank(1).  (Double-counted, Rank(2) would win.)
        let spec = JobSpec::new(10, 1, 1).with_inputs(vec![
            ChunkRef::slice(JobId(1), 0, 1),
            ChunkRef::slice(JobId(1), 1, 2),
            ChunkRef::all(JobId(2)),
        ]);
        let mut owners = HashMap::new();
        owners.insert(
            JobId(1),
            SourceLoc { job: JobId(1), owner: Rank(2), kept_on: None },
        );
        owners.insert(
            JobId(2),
            SourceLoc { job: JobId(2), owner: Rank(1), kept_on: None },
        );
        let mut bytes = HashMap::new();
        bytes.insert(JobId(1), 3000);
        bytes.insert(JobId(2), 5000);
        assert_eq!(
            choose_scheduler_comm_aware(
                &spec,
                &[],
                &owners,
                &bytes,
                &HashMap::new(),
                &HashMap::new(),
                &subs(),
                &link
            ),
            Rank(1)
        );
    }

    #[test]
    fn comm_aware_free_link_degrades_to_load_then_rank() {
        // With transfers priced at zero the score is pure est_load, and
        // full ties fall back to queue length then lowest rank — the same
        // final ordering as the PR 4 tie-break.
        let spec = JobSpec::new(10, 1, 1).with_inputs(vec![ChunkRef::all(JobId(1))]);
        let mut owners = HashMap::new();
        owners.insert(
            JobId(1),
            SourceLoc { job: JobId(1), owner: Rank(2), kept_on: None },
        );
        let mut bytes = HashMap::new();
        bytes.insert(JobId(1), 1 << 20);
        let free = FlatLink { alpha_us: 0.0, us_per_byte: 0.0 };
        let mut load = HashMap::new();
        load.insert(Rank(1), 3);
        load.insert(Rank(2), 1);
        assert_eq!(
            choose_scheduler_comm_aware(
                &spec,
                &[],
                &owners,
                &bytes,
                &load,
                &HashMap::new(),
                &subs(),
                &free
            ),
            Rank(2),
            "free transfers: least queue wins even against heavy affinity"
        );
    }

    #[test]
    fn policy_dispatches_on_the_knob() {
        let spec = JobSpec::new(10, 1, 1).with_inputs(vec![ChunkRef::all(JobId(1))]);
        let mut owners = HashMap::new();
        owners.insert(
            JobId(1),
            SourceLoc { job: JobId(1), owner: Rank(2), kept_on: None },
        );
        let mut bytes = HashMap::new();
        bytes.insert(JobId(1), 2000);
        let link = FlatLink { alpha_us: 20.0, us_per_byte: 1.0 };
        let off = choose_scheduler_policy(
            &spec,
            &[],
            &owners,
            &bytes,
            &HashMap::new(),
            &HashMap::new(),
            &subs(),
            None,
        );
        assert_eq!(off, Rank(1), "off = PR 4 light-affinity load balancing");
        let on = choose_scheduler_policy(
            &spec,
            &[],
            &owners,
            &bytes,
            &HashMap::new(),
            &HashMap::new(),
            &subs(),
            Some(&link),
        );
        assert_eq!(on, Rank(2));
    }

    #[test]
    fn preferred_worker_beats_best_fit_but_not_fits() {
        let mut a = WorkerSlot::new(Rank(1), 4);
        a.occupy(ThreadCount::Exact(1)); // 3 free: sloppier fit
        let mut b = WorkerSlot::new(Rank(2), 4);
        b.occupy(ThreadCount::Exact(2)); // 2 free: best fit
        let j = JobSpec::new(9, 1, 2);
        // No preference: best-fit picks b (same as choose_worker).
        assert_eq!(
            choose_worker_preferring(&j, None, &[], &[a.clone(), b.clone()]),
            WorkerChoice::Run(Rank(2))
        );
        // A pushed copy on a flips the choice.
        assert_eq!(
            choose_worker_preferring(&j, None, &[Rank(1)], &[a.clone(), b.clone()]),
            WorkerChoice::Run(Rank(1))
        );
        // A full preferred worker is not waited for — the job runs on the
        // fitting one instead.
        let mut full = WorkerSlot::new(Rank(3), 4);
        full.occupy(ThreadCount::Auto);
        assert_eq!(
            choose_worker_preferring(&j, None, &[Rank(3)], &[full, b]),
            WorkerChoice::Run(Rank(2))
        );
        // The hard kept pin still wins over any preference.
        assert_eq!(
            choose_worker_preferring(&j, Some(Rank(1)), &[Rank(2)], &[a]),
            WorkerChoice::Run(Rank(1))
        );
    }

    #[test]
    fn packing_two_2thread_jobs_on_4core_worker() {
        // The paper's J3/J4 example.
        let mut w = WorkerSlot::new(Rank(5), 4);
        let j3 = JobSpec::new(3, 2, 2);
        let j4 = JobSpec::new(4, 2, 2);
        assert_eq!(choose_worker(&j3, None, &[w.clone()]), WorkerChoice::Run(Rank(5)));
        w.occupy(j3.threads);
        assert_eq!(choose_worker(&j4, None, &[w.clone()]), WorkerChoice::Run(Rank(5)));
        w.occupy(j4.threads);
        // Third 2-thread job no longer fits.
        let j5 = JobSpec::new(5, 2, 2);
        assert_eq!(choose_worker(&j5, None, &[w.clone()]), WorkerChoice::Spawn);
        w.vacate(j3.threads);
        assert_eq!(choose_worker(&j5, None, &[w]), WorkerChoice::Run(Rank(5)));
    }

    #[test]
    fn auto_threads_take_whole_node() {
        let w = WorkerSlot::new(Rank(5), 4);
        let auto = JobSpec::new(1, 1, 0); // ThreadCount::Auto
        let mut w2 = w.clone();
        w2.occupy(auto.threads);
        assert_eq!(w2.free_cores, 0);
        let one = JobSpec::new(2, 1, 1);
        assert_eq!(choose_worker(&one, None, &[w2]), WorkerChoice::Spawn);
    }

    #[test]
    fn best_fit_prefers_tightest_slot() {
        let mut a = WorkerSlot::new(Rank(1), 4);
        a.occupy(ThreadCount::Exact(1)); // 3 free
        let mut b = WorkerSlot::new(Rank(2), 4);
        b.occupy(ThreadCount::Exact(2)); // 2 free
        let j = JobSpec::new(9, 1, 2);
        // Both fit; best-fit picks b (surplus 0 < surplus 1).
        assert_eq!(choose_worker(&j, None, &[a, b]), WorkerChoice::Run(Rank(2)));
    }

    #[test]
    fn bulk_assign_order_is_lpt_and_deterministic() {
        // Costly jobs first; equal costs (including the all-cold case)
        // fall back to JobId order so the pass is reproducible.
        let ordered = bulk_assign_order(vec![
            (JobId(4), 100),
            (JobId(1), 5000),
            (JobId(3), 100),
            (JobId(2), 0),
        ]);
        assert_eq!(
            ordered,
            vec![(JobId(1), 5000), (JobId(3), 100), (JobId(4), 100), (JobId(2), 0)]
        );
        // A cold cost table degrades to plain ready-queue (id) order.
        let cold = bulk_assign_order(vec![(JobId(9), 0), (JobId(2), 0), (JobId(5), 0)]);
        assert_eq!(cold, vec![(JobId(2), 0), (JobId(5), 0), (JobId(9), 0)]);
    }

    #[test]
    fn kept_affinity_waits_or_escalates() {
        let mut w = WorkerSlot::new(Rank(3), 2);
        w.occupy(ThreadCount::Exact(2));
        let j = JobSpec::new(9, 1, 1);
        assert_eq!(
            choose_worker(&j, Some(Rank(3)), &[w]),
            WorkerChoice::WaitFor(Rank(3))
        );
        assert_eq!(
            choose_worker(&j, Some(Rank(9)), &[]),
            WorkerChoice::Lost(Rank(9))
        );
    }
}
