//! Sub-scheduler result store (paper §3.1: "all other schedulers store
//! their jobs' results and further need to know how to assemble these
//! results that might be requested as input arguments by any other job").

use std::collections::HashMap;

use crate::data::FunctionData;
use crate::error::{Error, Result};
use crate::job::{ChunkRange, JobId};

/// Results owned by one sub-scheduler, plus transient copies of remote
/// results fetched for local consumers.
#[derive(Debug, Default)]
pub struct ResultStore {
    owned: HashMap<JobId, FunctionData>,
    /// Fetched from peers for pending local jobs; dropped after use.
    transient: HashMap<JobId, FunctionData>,
}

impl ResultStore {
    /// Empty store.
    pub fn new() -> Self {
        Self::default()
    }

    /// Store a result this scheduler owns.
    pub fn insert_owned(&mut self, job: JobId, data: FunctionData) {
        self.owned.insert(job, data);
    }

    /// Cache a remote result fetched for local consumers.
    pub fn insert_transient(&mut self, job: JobId, data: FunctionData) {
        self.transient.insert(job, data);
    }

    /// Serve `range` of a result (owned or transient), zero-copy.
    pub fn read(&self, job: JobId, range: ChunkRange) -> Result<FunctionData> {
        let data = self
            .owned
            .get(&job)
            .or_else(|| self.transient.get(&job))
            .ok_or(Error::ResultNotAvailable(job))?;
        let r = range.resolve(data.len())?;
        data.select(r)
    }

    /// Whether the result is readable here (owned or transient).
    pub fn contains(&self, job: JobId) -> bool {
        self.owned.contains_key(&job) || self.transient.contains_key(&job)
    }

    /// Whether this scheduler owns the result.
    pub fn is_owned(&self, job: JobId) -> bool {
        self.owned.contains_key(&job)
    }

    /// Release an owned result (master's `ReleaseResult`).
    pub fn release(&mut self, job: JobId) -> bool {
        self.owned.remove(&job).is_some()
    }

    /// Drop a transient copy (after the waiting jobs consumed it).
    pub fn drop_transient(&mut self, job: JobId) {
        self.transient.remove(&job);
    }

    /// Total bytes of owned results.
    pub fn owned_bytes(&self) -> usize {
        self.owned.values().map(|d| d.size_bytes()).sum()
    }

    /// Number of owned results.
    pub fn owned_count(&self) -> usize {
        self.owned.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DataChunk;

    fn data(k: usize) -> FunctionData {
        (0..k).map(|i| DataChunk::from_i32(vec![i as i32])).collect()
    }

    #[test]
    fn owned_and_transient_are_both_readable() {
        let mut s = ResultStore::new();
        s.insert_owned(JobId(1), data(3));
        s.insert_transient(JobId(2), data(2));
        assert_eq!(s.read(JobId(1), ChunkRange::All).unwrap().len(), 3);
        assert_eq!(s.read(JobId(2), ChunkRange::All).unwrap().len(), 2);
        assert!(s.is_owned(JobId(1)));
        assert!(!s.is_owned(JobId(2)));
    }

    #[test]
    fn release_only_touches_owned() {
        let mut s = ResultStore::new();
        s.insert_owned(JobId(1), data(1));
        s.insert_transient(JobId(2), data(1));
        assert!(s.release(JobId(1)));
        assert!(!s.release(JobId(2))); // transient not released this way
        s.drop_transient(JobId(2));
        assert!(!s.contains(JobId(2)));
    }

    #[test]
    fn range_reads() {
        let mut s = ResultStore::new();
        s.insert_owned(JobId(1), data(5));
        let sel = s.read(JobId(1), ChunkRange::Range { lo: 2, hi: 4 }).unwrap();
        assert_eq!(sel.len(), 2);
        assert_eq!(sel.chunk(0).unwrap().as_i32().unwrap(), &[2]);
        assert!(s.read(JobId(1), ChunkRange::Range { lo: 0, hi: 9 }).is_err());
        assert!(matches!(
            s.read(JobId(9), ChunkRange::All),
            Err(Error::ResultNotAvailable(JobId(9)))
        ));
    }

    #[test]
    fn byte_accounting() {
        let mut s = ResultStore::new();
        s.insert_owned(JobId(1), data(4)); // 4 x 4B
        assert_eq!(s.owned_bytes(), 16);
        assert_eq!(s.owned_count(), 1);
    }
}
