//! Sub-scheduler result store (paper §3.1: "all other schedulers store
//! their jobs' results and further need to know how to assemble these
//! results that might be requested as input arguments by any other job").
//!
//! Since DESIGN.md §16 the store is byte-budgeted: every owned result and
//! transient copy is charged against a [`BudgetLedger`]; when over budget
//! the store evicts by the configured [`EvictionPolicy`] — transient
//! copies are discarded (they can always be re-fetched from their owner),
//! owned results are spilled to disk (they are the lineage the rest of
//! the run depends on, and the master's final collection treats an
//! owner-side miss as fatal, so owned entries are never discard-evicted).

use std::collections::{HashMap, HashSet};
use std::path::PathBuf;

use crate::data::bounded::{self, BudgetLedger, EvictionPolicy};
use crate::data::FunctionData;
use crate::error::{Error, Result};
use crate::job::{ChunkRange, JobId};

/// An owned result currently living in its spill file, not in memory.
#[derive(Debug, Clone, Copy)]
struct SpillEntry {
    /// In-memory size when resident (what re-admission will charge).
    bytes: u64,
    /// Locally measured recompute cost, carried across the spill.
    est_recompute_us: Option<f64>,
}

/// What one [`ResultStore::enforce_budget`] pass did; the sub-scheduler
/// folds this into the metrics snapshot.
#[derive(Debug, Default)]
pub struct EvictReport {
    /// Transient copies discarded (re-fetchable from their owner).
    pub discarded: Vec<JobId>,
    /// Owned results written to their spill file and dropped from memory.
    pub spilled: Vec<JobId>,
    /// Pinned entries that outranked a victim and were skipped.
    pub pin_skips: u64,
}

impl EvictReport {
    /// Total evictions (discards + spills).
    pub fn evictions(&self) -> u64 {
        (self.discarded.len() + self.spilled.len()) as u64
    }
}

/// Results owned by one sub-scheduler, plus transient copies of remote
/// results fetched for local consumers.
#[derive(Debug, Default)]
pub struct ResultStore {
    owned: HashMap<JobId, FunctionData>,
    /// Fetched from peers for pending local jobs; dropped after use.
    transient: HashMap<JobId, FunctionData>,
    /// Byte-budget accounting over `owned` + `transient` (DESIGN.md §16).
    ledger: BudgetLedger,
    /// Owned results evicted to disk, readable back via
    /// [`Self::ensure_resident`].
    spilled: HashMap<JobId, SpillEntry>,
    spill_dir: Option<PathBuf>,
    policy: EvictionPolicy,
}

impl ResultStore {
    /// Empty, unbounded store (today's behaviour bit-for-bit).
    pub fn new() -> Self {
        Self::default()
    }

    /// Empty store with a byte budget (0 = unbounded) and an optional
    /// spill directory enabling owned-result eviction.
    pub fn with_budget(
        budget_bytes: u64,
        spill_dir: Option<PathBuf>,
        policy: EvictionPolicy,
    ) -> Self {
        ResultStore {
            ledger: BudgetLedger::new(budget_bytes),
            spill_dir,
            policy,
            ..Default::default()
        }
    }

    /// Store a result this scheduler owns.
    pub fn insert_owned(&mut self, job: JobId, data: FunctionData) {
        self.insert_owned_with_cost(job, data, None);
    }

    /// Store an owned result together with its measured execution µs —
    /// the recompute-cost input of the eviction score.
    pub fn insert_owned_with_cost(
        &mut self,
        job: JobId,
        data: FunctionData,
        est_recompute_us: Option<f64>,
    ) {
        if self.spilled.remove(&job).is_some() {
            if let Some(dir) = &self.spill_dir {
                bounded::spill_remove(dir, job);
            }
        }
        // Ownership displaces a stale transient copy (a result fetched
        // here before this scheduler was made its owner by recovery);
        // keeping both would double the resident bytes behind one charge.
        if self.transient.remove(&job).is_some() {
            self.ledger.release(job);
        }
        self.ledger.charge(job, data.size_bytes() as u64, est_recompute_us);
        self.owned.insert(job, data);
    }

    /// Cache a remote result fetched for local consumers.
    pub fn insert_transient(&mut self, job: JobId, data: FunctionData) {
        // Never shadow an owned result (resident or spilled) with a
        // transient copy: ownership charges would double-count.
        if self.owned.contains_key(&job) || self.spilled.contains_key(&job) {
            return;
        }
        self.ledger.charge(job, data.size_bytes() as u64, None);
        self.transient.insert(job, data);
    }

    /// Serve `range` of a result (owned or transient), zero-copy.
    pub fn read(&mut self, job: JobId, range: ChunkRange) -> Result<FunctionData> {
        self.ledger.touch(job);
        let data = self
            .owned
            .get(&job)
            .or_else(|| self.transient.get(&job))
            .ok_or(Error::ResultNotAvailable(job))?;
        let r = range.resolve(data.len())?;
        data.select(r)
    }

    /// Whether a byte budget is in force (the `memory_budget_bytes`
    /// knob was set).
    pub fn is_bounded(&self) -> bool {
        self.ledger.is_bounded()
    }

    /// Whether the result is readable here right now (owned or
    /// transient, in memory — a spilled result is *not* readable until
    /// [`Self::ensure_resident`] brings it back).
    pub fn contains(&self, job: JobId) -> bool {
        self.owned.contains_key(&job) || self.transient.contains_key(&job)
    }

    /// Whether this scheduler owns the result (resident or spilled).
    pub fn is_owned(&self, job: JobId) -> bool {
        self.owned.contains_key(&job) || self.spilled.contains_key(&job)
    }

    /// Whether `job` currently lives in its spill file.
    pub fn is_spilled(&self, job: JobId) -> bool {
        self.spilled.contains_key(&job)
    }

    /// In-memory size a spilled result will re-admit at (0 if not
    /// spilled).
    pub fn spilled_bytes(&self, job: JobId) -> u64 {
        self.spilled.get(&job).map(|e| e.bytes).unwrap_or(0)
    }

    /// Carried recompute estimate of a spilled result.
    pub fn spilled_estimate(&self, job: JobId) -> Option<f64> {
        self.spilled.get(&job).and_then(|e| e.est_recompute_us)
    }

    /// Bring `job` back into memory if it was spilled.  Returns `true`
    /// when the entry is resident afterwards, `false` when the store has
    /// never held it (the caller's ordinary miss path applies).
    pub fn ensure_resident(&mut self, job: JobId) -> Result<bool> {
        if self.contains(job) {
            return Ok(true);
        }
        let Some(entry) = self.spilled.get(&job).copied() else {
            return Ok(false);
        };
        let dir = self
            .spill_dir
            .as_ref()
            .ok_or_else(|| Error::Config("spilled entry without spill_dir".into()))?
            .clone();
        let data = bounded::spill_read(&dir, job)?;
        self.spilled.remove(&job);
        bounded::spill_remove(&dir, job);
        self.ledger.charge(job, entry.bytes, entry.est_recompute_us);
        self.owned.insert(job, data);
        Ok(true)
    }

    /// Drop a spilled result without reading it back — the sub declares
    /// it lost and lets §6 recovery recompute it from lineage.
    pub fn forget_spilled(&mut self, job: JobId) -> bool {
        if self.spilled.remove(&job).is_none() {
            return false;
        }
        if let Some(dir) = &self.spill_dir {
            bounded::spill_remove(dir, job);
        }
        true
    }

    /// Release an owned result (master's `ReleaseResult`), resident or
    /// spilled.
    pub fn release(&mut self, job: JobId) -> bool {
        if self.owned.remove(&job).is_some() {
            self.ledger.release(job);
            return true;
        }
        self.forget_spilled(job)
    }

    /// Drop a transient copy (after the waiting jobs consumed it).
    pub fn drop_transient(&mut self, job: JobId) {
        if self.transient.remove(&job).is_some() {
            self.ledger.release(job);
        }
    }

    /// Bring the store back under budget: discard transient victims,
    /// spill owned victims (owned entries are unevictable without a
    /// spill directory), skip pinned entries.  No-op when unbounded.
    pub fn enforce_budget(&mut self, pinned: &HashSet<JobId>) -> EvictReport {
        let mut report = EvictReport::default();
        if !self.ledger.is_bounded() {
            return report;
        }
        // Without a spill directory owned results cannot be evicted at
        // all — discarding one would make the owner lie to the master's
        // availability map (fatal at final collection, DESIGN.md §16).
        let unevictable: HashSet<JobId> = if self.spill_dir.is_none() {
            self.owned.keys().copied().collect()
        } else {
            HashSet::new()
        };
        let plan = self.ledger.plan_evictions(self.policy, pinned, &unevictable);
        report.pin_skips = plan.pin_skips;
        for job in plan.victims {
            if self.transient.contains_key(&job) {
                self.transient.remove(&job);
                self.ledger.release(job);
                report.discarded.push(job);
            } else if let (Some(data), Some(dir)) =
                (self.owned.get(&job), self.spill_dir.clone())
            {
                if bounded::spill_write(&dir, job, data).is_err() {
                    continue; // disk refused: leave it resident
                }
                self.spilled.insert(
                    job,
                    SpillEntry {
                        bytes: self.ledger.bytes_of(job),
                        est_recompute_us: self.ledger.estimate(job),
                    },
                );
                self.owned.remove(&job);
                self.ledger.release(job);
                report.spilled.push(job);
            }
        }
        report
    }

    /// Record the measured execution µs of an already-stored result.
    pub fn note_recompute_cost(&mut self, job: JobId, exec_us: u64) {
        if exec_us > 0 {
            self.ledger.set_estimate(job, exec_us as f64);
        }
    }

    /// Bytes currently charged (owned + transient, in memory).
    pub fn resident_bytes(&self) -> u64 {
        self.ledger.resident_bytes()
    }

    /// High-water mark of charged bytes (the `store_bytes` metric).
    pub fn peak_bytes(&self) -> u64 {
        self.ledger.peak_bytes()
    }

    /// Total bytes of owned results in memory.
    pub fn owned_bytes(&self) -> usize {
        self.owned.values().map(|d| d.size_bytes()).sum()
    }

    /// Number of owned results in memory.
    pub fn owned_count(&self) -> usize {
        self.owned.len()
    }

    /// Debug-only ledger balance check: every byte charged is a byte
    /// still resident — charges and releases must pair up exactly
    /// (DESIGN.md §16).  Called at sub shutdown.
    pub fn debug_assert_balanced(&self) {
        if cfg!(debug_assertions) {
            let actual: u64 = self
                .owned
                .values()
                .chain(self.transient.values())
                .map(|d| d.size_bytes() as u64)
                .sum();
            debug_assert_eq!(
                self.ledger.resident_bytes(),
                actual,
                "store ledger out of balance: charged {} B, resident {} B",
                self.ledger.resident_bytes(),
                actual
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DataChunk;

    fn data(k: usize) -> FunctionData {
        (0..k).map(|i| DataChunk::from_i32(vec![i as i32])).collect()
    }

    fn spill_dir(tag: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("hypar_store_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn owned_and_transient_are_both_readable() {
        let mut s = ResultStore::new();
        s.insert_owned(JobId(1), data(3));
        s.insert_transient(JobId(2), data(2));
        assert_eq!(s.read(JobId(1), ChunkRange::All).unwrap().len(), 3);
        assert_eq!(s.read(JobId(2), ChunkRange::All).unwrap().len(), 2);
        assert!(s.is_owned(JobId(1)));
        assert!(!s.is_owned(JobId(2)));
    }

    #[test]
    fn release_only_touches_owned() {
        let mut s = ResultStore::new();
        s.insert_owned(JobId(1), data(1));
        s.insert_transient(JobId(2), data(1));
        assert!(s.release(JobId(1)));
        assert!(!s.release(JobId(2))); // transient not released this way
        s.drop_transient(JobId(2));
        assert!(!s.contains(JobId(2)));
        s.debug_assert_balanced();
    }

    #[test]
    fn range_reads() {
        let mut s = ResultStore::new();
        s.insert_owned(JobId(1), data(5));
        let sel = s.read(JobId(1), ChunkRange::Range { lo: 2, hi: 4 }).unwrap();
        assert_eq!(sel.len(), 2);
        assert_eq!(sel.chunk(0).unwrap().as_i32().unwrap(), &[2]);
        assert!(s.read(JobId(1), ChunkRange::Range { lo: 0, hi: 9 }).is_err());
        assert!(matches!(
            s.read(JobId(9), ChunkRange::All),
            Err(Error::ResultNotAvailable(JobId(9)))
        ));
    }

    #[test]
    fn byte_accounting() {
        let mut s = ResultStore::new();
        s.insert_owned(JobId(1), data(4)); // 4 x 4B
        assert_eq!(s.owned_bytes(), 16);
        assert_eq!(s.owned_count(), 1);
        assert_eq!(s.resident_bytes(), 16);
        s.debug_assert_balanced();
    }

    #[test]
    fn owned_insert_displaces_stale_transient_copy() {
        let mut s = ResultStore::new();
        s.insert_transient(JobId(4), data(2)); // fetched before ownership
        s.insert_owned(JobId(4), data(5)); // recovery made us the owner
        assert_eq!(s.read(JobId(4), ChunkRange::All).unwrap().len(), 5);
        assert_eq!(s.resident_bytes(), 20);
        s.debug_assert_balanced();
    }

    #[test]
    fn transient_discard_eviction_frees_budget() {
        let mut s = ResultStore::with_budget(20, None, EvictionPolicy::CostAwareLru);
        s.insert_owned(JobId(1), data(4)); // 16 B owned — unevictable (no dir)
        s.insert_transient(JobId(2), data(4)); // 16 B: 32 resident, 12 over
        let report = s.enforce_budget(&HashSet::new());
        assert_eq!(report.discarded, vec![JobId(2)]);
        assert!(report.spilled.is_empty());
        assert!(s.contains(JobId(1)));
        assert!(!s.contains(JobId(2)));
        assert_eq!(s.resident_bytes(), 16);
        s.debug_assert_balanced();
    }

    #[test]
    fn owned_spill_eviction_and_readmission() {
        let dir = spill_dir("spill");
        let mut s = ResultStore::with_budget(
            20,
            Some(dir.clone()),
            EvictionPolicy::CostAwareLru,
        );
        s.insert_owned_with_cost(JobId(1), data(4), Some(5.0));
        s.insert_owned_with_cost(JobId(2), data(4), Some(50_000.0));
        // 32 B resident over a 20 B budget: the cheap-to-recompute job 1
        // spills first and suffices.
        let report = s.enforce_budget(&HashSet::new());
        assert_eq!(report.spilled, vec![JobId(1)]);
        assert!(s.is_owned(JobId(1)) && s.is_spilled(JobId(1)));
        assert!(!s.contains(JobId(1)));
        assert_eq!(s.spilled_bytes(JobId(1)), 16);
        assert_eq!(s.spilled_estimate(JobId(1)), Some(5.0));
        // Read-back restores the exact value and re-charges the ledger.
        assert!(s.ensure_resident(JobId(1)).unwrap());
        assert!(!s.is_spilled(JobId(1)));
        let back = s.read(JobId(1), ChunkRange::All).unwrap();
        assert_eq!(back.len(), 4);
        assert_eq!(back.chunk(3).unwrap().as_i32().unwrap(), &[3]);
        assert_eq!(s.resident_bytes(), 32);
        s.debug_assert_balanced();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn pinned_entries_survive_enforcement() {
        let mut s = ResultStore::with_budget(10, None, EvictionPolicy::CostAwareLru);
        s.insert_transient(JobId(1), data(4)); // 16 B, over budget, pinned
        let pinned: HashSet<JobId> = [JobId(1)].into_iter().collect();
        let report = s.enforce_budget(&pinned);
        assert!(report.discarded.is_empty());
        assert_eq!(report.pin_skips, 1);
        assert!(s.contains(JobId(1)));
    }

    #[test]
    fn release_of_spilled_result_removes_the_file() {
        let dir = spill_dir("release");
        let mut s =
            ResultStore::with_budget(1, Some(dir.clone()), EvictionPolicy::Lru);
        s.insert_owned(JobId(7), data(2));
        let report = s.enforce_budget(&HashSet::new());
        assert_eq!(report.spilled, vec![JobId(7)]);
        assert!(crate::data::bounded::spill_path(&dir, JobId(7)).exists());
        assert!(s.release(JobId(7)));
        assert!(!crate::data::bounded::spill_path(&dir, JobId(7)).exists());
        assert!(!s.is_owned(JobId(7)));
        assert_eq!(s.resident_bytes(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn budget_accounting_exact_after_evict_spill_readmit_cycles() {
        let dir = spill_dir("cycles");
        let mut s = ResultStore::with_budget(
            40,
            Some(dir.clone()),
            EvictionPolicy::CostAwareLru,
        );
        for round in 0..3 {
            s.insert_owned(JobId(1), data(4));
            s.insert_owned(JobId(2), data(4));
            s.insert_transient(JobId(3), data(4));
            let _ = s.enforce_budget(&HashSet::new());
            assert!(s.resident_bytes() <= 40, "round {round} over budget");
            assert!(s.ensure_resident(JobId(1)).unwrap());
            assert!(s.ensure_resident(JobId(2)).unwrap());
            let total = s.owned_bytes() as u64;
            assert_eq!(total, 32, "round {round}");
            s.drop_transient(JobId(3));
            assert!(s.release(JobId(1)));
            assert!(s.release(JobId(2)));
            assert_eq!(s.resident_bytes(), 0, "round {round}");
            s.debug_assert_balanced();
        }
        assert!(s.peak_bytes() >= 40);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
