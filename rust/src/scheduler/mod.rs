//! The scheduler hierarchy — the paper's §3 runtime architecture.
//!
//! ```text
//!                 ┌────────────┐  Assign / JobDone / Inject
//!                 │ master S0  │◄──────────────────────────┐
//!                 └─────┬──────┘                            │
//!          Assign       │ holds the ONLY copy of the        │
//!        ┌──────────────┤ algorithm description; stores     │
//!        ▼              ▼ no job data (paper §3.1)          │
//!   ┌─────────┐    ┌─────────┐   FetchResult / ResultData   │
//!   │ sub S1  │◄──►│ sub S2  │◄─────────────────────────────┘
//!   └──┬──────┘    └───┬─────┘   (schedulers serve results
//!      │ Exec / Done   │          to each other)
//!   ┌──▼──┐ ┌──▼──┐ ┌──▼──┐
//!   │ W1  │ │ W2  │ │ W3  │   workers: dynamically spawned,
//!   └─────┘ └─────┘ └─────┘   isolated, keep-results caches
//! ```
//!
//! This module defines the control-plane message protocol ([`FwMsg`]);
//! [`master`] and [`sub`] implement the two scheduler roles, [`graph`] the
//! dependency-DAG dataflow executor state, [`placement`] the packing
//! policies, [`store`] the result store and [`dynamic`] the runtime
//! job-injection resolution.

pub mod dynamic;
pub mod graph;
pub mod master;
pub mod placement;
pub mod store;
pub mod sub;

use crate::comm::{Rank, Tag, WireSize};
use crate::data::FunctionData;
use crate::job::{ChunkRange, Injection, JobId, JobSpec, ThreadCount};

/// The single user tag of the control plane (matching is by content, the
/// event loops consume everything).
pub const TAG_CTRL: Tag = Tag(1);

/// Where a job's result lives: which sub-scheduler owns it, and — under
/// keep-results — which of its workers physically retains it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SourceLoc {
    /// The producing job.
    pub job: JobId,
    /// Sub-scheduler owning (storing or routing) the result.
    pub owner: Rank,
    /// Worker physically retaining it under keep-results, if any.
    pub kept_on: Option<Rank>,
}

/// One part of a job's assembled input.
#[derive(Debug, Clone)]
pub enum InputPart {
    /// Chunks shipped with the request.
    Data(FunctionData),
    /// Chunks the executing worker already retains (keep-results locality:
    /// zero transfer).
    Kept { job: JobId, range: ChunkRange },
}

impl InputPart {
    /// Bytes physically shipped with this part (0 for kept inputs).
    pub fn shipped_bytes(&self) -> usize {
        match self {
            InputPart::Data(d) => d.size_bytes(),
            InputPart::Kept { .. } => 0,
        }
    }
}

/// A fully resolved execution request (sub-scheduler → worker).
#[derive(Debug, Clone)]
pub struct ExecRequest {
    /// The job to run.
    pub spec: JobSpec,
    /// Resolved input parts, in the spec's reference order.
    pub input: Vec<InputPart>,
}

impl ExecRequest {
    /// Total bytes physically shipped with the request.
    pub fn shipped_bytes(&self) -> usize {
        self.input.iter().map(|p| p.shipped_bytes()).sum()
    }
}

/// Control-plane protocol. One message type for all role pairs keeps the
/// event loops single-recv (no cross-message blocking → no deadlock).
#[derive(Debug, Clone)]
pub enum FwMsg {
    // ------------------------------------------------- master → sub
    /// Execute this job; `sources` locates every referenced result.
    Assign {
        /// The job to execute.
        spec: JobSpec,
        /// Location of every referenced result.
        sources: Vec<SourceLoc>,
    },
    /// Speculative-prefetch hint (dataflow mode, DESIGN.md §7): `job` is a
    /// `Waiting` node with all inputs but one materialised and this
    /// scheduler is its probable assignment target; pull the listed remote
    /// sources now so the eventual `Assign` finds them warm.  Purely
    /// advisory — a wrong prediction costs one redundant transfer (now
    /// reclaimed by a cancel hint), never correctness.
    Prefetch {
        /// The predicted job (informational).
        job: JobId,
        /// The predicted job's thread request — lets the hinted scheduler
        /// predict the worker too and warm its cache (kept-result
        /// prefetch, DESIGN.md §10).
        threads: ThreadCount,
        /// Remote sources worth pulling early.
        sources: Vec<SourceLoc>,
    },
    /// Free a stored (or kept) result.
    ReleaseResult {
        /// The producing job whose result is released.
        job: JobId,
    },
    /// End of run: shut down workers and exit.
    Shutdown,

    // ------------------------------------------------- sub → master
    /// Job completed; `kept_on` set when the worker retained the output.
    JobDone {
        /// The completed job.
        job: JobId,
        /// Worker retaining the output under keep-results, if any.
        kept_on: Option<Rank>,
        /// Size of the stored output (0 when kept).
        output_bytes: u64,
        /// Chunk count of the stored output (0 when kept).
        chunks: usize,
        /// Dynamic job injections the function recorded.
        injections: Vec<Injection>,
        /// Worker-observed execution time — the feedback sample of the
        /// master's cost model (DESIGN.md §9; 0 = not measured).
        exec_us: u64,
    },
    /// Job execution failed (user function error).
    JobError {
        /// The failing job.
        job: JobId,
        /// Stringified failure reason.
        msg: String,
    },
    /// A worker died; its retained results and running jobs are listed.
    WorkerLostReport {
        /// The dead worker rank.
        worker: Rank,
        /// Kept results that died with it.
        lost: Vec<JobId>,
        /// Jobs that were executing on it.
        running: Vec<JobId>,
    },
    /// Could not assemble inputs (a source vanished mid-assignment);
    /// master re-queues the job through recovery.
    JobAborted {
        /// The aborted job.
        job: JobId,
        /// The input result that could not be found.
        missing: JobId,
    },

    // ------------------------------------------------- sub ↔ sub (+ master)
    /// Request chunks of a stored result; reply goes to `reply_to`.
    FetchResult {
        /// The producing job whose result is wanted.
        job: JobId,
        /// Which chunks.
        range: ChunkRange,
        /// Rank to send the `ResultData` reply to.
        reply_to: Rank,
    },
    /// Reply to `FetchResult`.
    ResultData {
        /// The producing job.
        job: JobId,
        /// The requested chunks.
        data: FunctionData,
    },
    /// The requested result is gone (lost worker); requester aborts the
    /// dependent job back to the master.
    ResultUnavailable {
        /// The missing result's producing job.
        job: JobId,
    },

    // ------------------------------------------------- sub → worker
    /// Run a fully resolved request on the receiving worker.
    Exec(ExecRequest),
    /// Kept-result prefetch (DESIGN.md §10): warm the worker's retained
    /// cache with a copy of a result a predicted assignment will consume,
    /// so the eventual `Exec` references it as a kept input (zero shipped
    /// bytes at dispatch).  Sent on the same FIFO channel as `Exec`, so
    /// the copy is always cached before any request referencing it.  The
    /// worker inserts silently; the copy is dropped by the ordinary
    /// `DropKept` path when released or mispredicted.
    CachePush {
        /// The producing job whose result is being pushed.
        job: JobId,
        /// The full result.
        data: FunctionData,
    },
    /// Upload a retained result to the scheduler.
    PullKept {
        /// The retained result's producing job.
        job: JobId,
    },
    /// Retained result no longer needed.
    DropKept {
        /// The retained result's producing job.
        job: JobId,
    },
    /// Clean shutdown.
    WorkerShutdown,

    // ------------------------------------------------- worker → sub
    /// Execution finished successfully.
    ExecDone {
        /// The completed job.
        job: JobId,
        /// The output; `None` when retained under keep-results.
        data: Option<FunctionData>,
        /// Dynamic job injections the function recorded.
        injections: Vec<Injection>,
        /// Measured execution microseconds (queue wait excluded).
        exec_us: u64,
    },
    /// Execution failed (user error or contained panic).
    ExecFailed {
        /// The failing job.
        job: JobId,
        /// Stringified failure reason.
        msg: String,
    },
    /// Reply to `PullKept` (`exec_us` 0), and the worker's deposit-to-self
    /// of a pool-executed keep-results output (`exec_us` = measured
    /// execution time, forwarded on the `ExecDone` ack).
    KeptData {
        /// The producing job.
        job: JobId,
        /// The retained output.
        data: FunctionData,
        /// Measured execution microseconds (0 on pull replies).
        exec_us: u64,
    },
}

impl WireSize for FwMsg {
    fn wire_size(&self) -> usize {
        const CTRL: usize = 32; // envelope-ish fixed cost of control fields
        match self {
            FwMsg::Assign { spec, sources } => {
                CTRL + spec.inputs.len() * 24 + sources.len() * 24
            }
            FwMsg::Prefetch { sources, .. } => CTRL + sources.len() * 24,
            FwMsg::Exec(req) => CTRL + req.shipped_bytes(),
            FwMsg::ExecDone { data, injections, .. } => {
                CTRL + data.as_ref().map_or(0, |d| d.size_bytes())
                    + injections.iter().map(|i| i.jobs.len() * 32).sum::<usize>()
            }
            FwMsg::JobDone { injections, .. } => {
                CTRL + injections.iter().map(|i| i.jobs.len() * 32).sum::<usize>()
            }
            FwMsg::ResultData { data, .. }
            | FwMsg::KeptData { data, .. }
            | FwMsg::CachePush { data, .. } => CTRL + data.size_bytes(),
            FwMsg::JobError { msg, .. } | FwMsg::ExecFailed { msg, .. } => CTRL + msg.len(),
            FwMsg::WorkerLostReport { lost, running, .. } => {
                CTRL + (lost.len() + running.len()) * 8
            }
            _ => CTRL,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DataChunk;

    #[test]
    fn exec_request_counts_only_shipped_bytes() {
        let req = ExecRequest {
            spec: JobSpec::new(1, 1, 1),
            input: vec![
                InputPart::Data(FunctionData::of_f32(vec![0.0; 10])), // 40 B
                InputPart::Kept { job: JobId(2), range: ChunkRange::All }, // 0 B
            ],
        };
        assert_eq!(req.shipped_bytes(), 40);
        assert!(FwMsg::Exec(req).wire_size() >= 40);
    }

    #[test]
    fn result_data_wire_size_scales() {
        let small = FwMsg::ResultData {
            job: JobId(1),
            data: FunctionData::of_f32(vec![0.0; 1]),
        };
        let big = FwMsg::ResultData {
            job: JobId(1),
            data: FunctionData::from_chunks(vec![DataChunk::from_f32(vec![0.0; 1000])]),
        };
        assert!(big.wire_size() > small.wire_size() + 3000);
    }
}
