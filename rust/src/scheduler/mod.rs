//! The scheduler hierarchy — the paper's §3 runtime architecture.
//!
//! ```text
//!                 ┌────────────┐  Assign / JobDone / Inject
//!                 │ master S0  │◄──────────────────────────┐
//!                 └─────┬──────┘                            │
//!          Assign       │ holds the ONLY copy of the        │
//!        ┌──────────────┤ algorithm description; stores     │
//!        ▼              ▼ no job data (paper §3.1)          │
//!   ┌─────────┐    ┌─────────┐   FetchResult / ResultData   │
//!   │ sub S1  │◄──►│ sub S2  │◄─────────────────────────────┘
//!   └──┬──────┘    └───┬─────┘   (schedulers serve results
//!      │ Exec / Done   │          to each other)
//!   ┌──▼──┐ ┌──▼──┐ ┌──▼──┐
//!   │ W1  │ │ W2  │ │ W3  │   workers: dynamically spawned,
//!   └─────┘ └─────┘ └─────┘   isolated, keep-results caches
//! ```
//!
//! This module defines the control-plane message protocol ([`FwMsg`]);
//! [`master`] and [`sub`] implement the two scheduler roles, [`graph`] the
//! dependency-DAG dataflow executor state, [`placement`] the packing
//! policies, [`store`] the result store and [`dynamic`] the runtime
//! job-injection resolution.

pub mod dynamic;
pub mod graph;
pub mod master;
pub mod placement;
pub mod store;
pub mod sub;
pub mod wire;

use std::time::{Duration, Instant};

use crate::comm::{wire_size_sum, Comm, Rank, Tag, WireSize};
use crate::data::FunctionData;
use crate::job::{ChunkRange, Injection, JobId, JobSpec, ThreadCount};
use crate::metrics::MetricsCollector;

/// The single user tag of the control plane (matching is by content, the
/// event loops consume everything).
pub const TAG_CTRL: Tag = Tag(1);

/// Explicit drop site for a control message its receiver cannot route
/// (DESIGN.md §13, invariant L1).  Every receiver loop's catch-all arm
/// funnels through here instead of silently discarding: debug builds print
/// the dropped message, so widening the protocol without teaching a
/// receiver shows up in test output instead of as a silent hang.  Release
/// builds stay quiet — an unroutable message is ignorable by construction
/// (the sender gets no reply either way).
pub(crate) fn log_unroutable(role: &str, msg: &FwMsg) {
    if cfg!(debug_assertions) {
        eprintln!("hypar[{role}]: dropping unroutable control message {msg:?}");
    }
}

/// Where a job's result lives: which sub-scheduler owns it, and — under
/// keep-results — which of its workers physically retains it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SourceLoc {
    /// The producing job.
    pub job: JobId,
    /// Sub-scheduler owning (storing or routing) the result.
    pub owner: Rank,
    /// Worker physically retaining it under keep-results, if any.
    pub kept_on: Option<Rank>,
}

/// One part of a job's assembled input.
#[derive(Debug, Clone)]
pub enum InputPart {
    /// Chunks shipped with the request.
    Data(FunctionData),
    /// Chunks the executing worker already retains (keep-results locality:
    /// zero transfer).
    Kept { job: JobId, range: ChunkRange },
}

impl InputPart {
    /// Bytes physically shipped with this part (0 for kept inputs).
    pub fn shipped_bytes(&self) -> usize {
        match self {
            InputPart::Data(d) => d.size_bytes(),
            InputPart::Kept { .. } => 0,
        }
    }
}

/// A fully resolved execution request (sub-scheduler → worker).
#[derive(Debug, Clone)]
pub struct ExecRequest {
    /// The job to run.
    pub spec: JobSpec,
    /// Resolved input parts, in the spec's reference order.
    pub input: Vec<InputPart>,
}

impl ExecRequest {
    /// Total bytes physically shipped with the request.
    pub fn shipped_bytes(&self) -> usize {
        self.input.iter().map(|p| p.shipped_bytes()).sum()
    }
}

/// Control-plane protocol. One message type for all role pairs keeps the
/// event loops single-recv (no cross-message blocking → no deadlock).
#[derive(Debug, Clone)]
pub enum FwMsg {
    // ------------------------------------------------- master → sub
    /// Execute this job; `sources` locates every referenced result.
    Assign {
        /// The job to execute.
        spec: JobSpec,
        /// Location of every referenced result.
        sources: Vec<SourceLoc>,
    },
    /// Speculative-prefetch hint (dataflow mode, DESIGN.md §7): `job` is a
    /// `Waiting` node with all inputs but one materialised and this
    /// scheduler is its probable assignment target; pull the listed remote
    /// sources now so the eventual `Assign` finds them warm.  Purely
    /// advisory — a wrong prediction costs one redundant transfer (now
    /// reclaimed by a cancel hint), never correctness.
    Prefetch {
        /// The predicted job (informational).
        job: JobId,
        /// The predicted job's thread request — lets the hinted scheduler
        /// predict the worker too and warm its cache (kept-result
        /// prefetch, DESIGN.md §10).
        threads: ThreadCount,
        /// Remote sources worth pulling early.
        sources: Vec<SourceLoc>,
    },
    /// Free a stored (or kept) result.
    ReleaseResult {
        /// The producing job whose result is released.
        job: JobId,
    },
    /// End of run: shut down workers and exit.
    Shutdown,

    // ------------------------------------------------- sub → master
    /// Job completed; `kept_on` set when the worker retained the output.
    JobDone {
        /// The completed job.
        job: JobId,
        /// Worker retaining the output under keep-results, if any.
        kept_on: Option<Rank>,
        /// Size of the stored output (0 when kept).
        output_bytes: u64,
        /// Chunk count of the stored output (0 when kept).
        chunks: usize,
        /// Dynamic job injections the function recorded.
        injections: Vec<Injection>,
        /// Worker-observed execution time — the feedback sample of the
        /// master's cost model (DESIGN.md §9; 0 = not measured).
        exec_us: u64,
    },
    /// Job execution failed (user function error).
    JobError {
        /// The failing job.
        job: JobId,
        /// Stringified failure reason.
        msg: String,
    },
    /// A worker died; its retained results and running jobs are listed.
    WorkerLostReport {
        /// The dead worker rank.
        worker: Rank,
        /// Kept results that died with it.
        lost: Vec<JobId>,
        /// Jobs that were executing on it.
        running: Vec<JobId>,
    },
    /// Could not assemble inputs (a source vanished mid-assignment);
    /// master re-queues the job through recovery.
    JobAborted {
        /// The aborted job.
        job: JobId,
        /// The input result that could not be found.
        missing: JobId,
    },

    // ------------------------------------------------- sub ↔ sub (+ master)
    /// Request chunks of a stored result; reply goes to `reply_to`.
    FetchResult {
        /// The producing job whose result is wanted.
        job: JobId,
        /// Which chunks.
        range: ChunkRange,
        /// Rank to send the `ResultData` reply to.
        reply_to: Rank,
    },
    /// Reply to `FetchResult`.
    ResultData {
        /// The producing job.
        job: JobId,
        /// The requested chunks.
        data: FunctionData,
    },
    /// The requested result is gone (lost worker); requester aborts the
    /// dependent job back to the master.
    ResultUnavailable {
        /// The missing result's producing job.
        job: JobId,
    },

    // ------------------------------------------------- sub → worker
    /// Run a fully resolved request on the receiving worker.
    Exec(ExecRequest),
    /// Kept-result prefetch (DESIGN.md §10): warm the worker's retained
    /// cache with a copy of a result a predicted assignment will consume,
    /// so the eventual `Exec` references it as a kept input (zero shipped
    /// bytes at dispatch).  Sent on the same FIFO channel as `Exec`, so
    /// the copy is always cached before any request referencing it.  The
    /// worker inserts silently; the copy is dropped by the ordinary
    /// `DropKept` path when released or mispredicted.
    CachePush {
        /// The producing job whose result is being pushed.
        job: JobId,
        /// The full result.
        data: FunctionData,
    },
    /// Upload a retained result to the scheduler.
    PullKept {
        /// The retained result's producing job.
        job: JobId,
    },
    /// Retained result no longer needed.
    DropKept {
        /// The retained result's producing job.
        job: JobId,
    },
    /// Clean shutdown.
    WorkerShutdown,

    // ------------------------------------------------- worker → sub
    /// Execution finished successfully.
    ExecDone {
        /// The completed job.
        job: JobId,
        /// The output; `None` when retained under keep-results.
        data: Option<FunctionData>,
        /// Dynamic job injections the function recorded.
        injections: Vec<Injection>,
        /// Measured execution microseconds (queue wait excluded).
        exec_us: u64,
    },
    /// Execution failed (user error or contained panic).
    ExecFailed {
        /// The failing job.
        job: JobId,
        /// Stringified failure reason.
        msg: String,
    },
    /// Reply to `PullKept` (`exec_us` 0), and the worker's deposit-to-self
    /// of a pool-executed keep-results output (`exec_us` = measured
    /// execution time, forwarded on the `ExecDone` ack).
    KeptData {
        /// The producing job.
        job: JobId,
        /// The retained output.
        data: FunctionData,
        /// Measured execution microseconds (0 on pull replies).
        exec_us: u64,
    },

    // ------------------------------------------------- liveness (§14)
    /// Master → sub liveness probe (DESIGN.md §14).  Piggybacked on the
    /// §12 coalesced batches when control traffic exists, shipped
    /// standalone when the link is idle — so a *silent* hung rank is
    /// probed even when the scheduler has nothing to say to it.
    Heartbeat,
    /// Sub → master liveness reply.  Receipt (like any other traffic from
    /// the rank) resets the sender's miss counter in the master's
    /// [`HeartbeatDetector`]; `heartbeat_miss_limit` consecutive silent
    /// intervals declare the rank lost.
    HeartbeatAck,

    // ------------------------------------------------- coalesced frames
    /// Coalesced control frame (DESIGN.md §12): several same-destination
    /// control messages shipped as one send.  Receivers unwrap the members
    /// **in order**, so per-(src,dst) FIFO delivery carries through
    /// batching — the §10 `CachePush`-before-`Exec` invariant holds
    /// exactly as on the unbatched wire.  Producers never nest batches
    /// (a frame contains only plain messages), but every receiver unwraps
    /// depth-first anyway, so a nested frame would still flatten in order.
    Batch(Vec<FwMsg>),
}

/// Per-entry wire charge of a [`SourceLoc`] hint (job id + owner rank +
/// kept-on option).  Shared by `Assign` and `Prefetch` so a source-location
/// hint costs the same wherever it rides and the α/β calibration stays
/// honest when hints move between message kinds (DESIGN.md §12).
const SRC_LOC_BYTES: usize = 24;
/// Per-entry wire charge of a spec's input chunk reference.
const CHUNK_REF_BYTES: usize = 24;

impl WireSize for FwMsg {
    fn wire_size(&self) -> usize {
        const CTRL: usize = 32; // envelope-ish fixed cost of control fields
        match self {
            FwMsg::Assign { spec, sources } => {
                CTRL + spec.inputs.len() * CHUNK_REF_BYTES
                    + sources.len() * SRC_LOC_BYTES
            }
            FwMsg::Prefetch { sources, .. } => CTRL + sources.len() * SRC_LOC_BYTES,
            FwMsg::Exec(req) => CTRL + req.shipped_bytes(),
            FwMsg::ExecDone { data, injections, .. } => {
                CTRL + data.as_ref().map_or(0, |d| d.size_bytes())
                    + injections.iter().map(|i| i.jobs.len() * 32).sum::<usize>()
            }
            FwMsg::JobDone { injections, .. } => {
                CTRL + injections.iter().map(|i| i.jobs.len() * 32).sum::<usize>()
            }
            FwMsg::ResultData { data, .. }
            | FwMsg::KeptData { data, .. }
            | FwMsg::CachePush { data, .. } => CTRL + data.size_bytes(),
            FwMsg::JobError { msg, .. } | FwMsg::ExecFailed { msg, .. } => CTRL + msg.len(),
            FwMsg::WorkerLostReport { lost, running, .. } => {
                CTRL + (lost.len() + running.len()) * 8
            }
            // One frame charge for the batch, then exactly the members'
            // own sizes: coalescing saves (n-1) CTRL charges plus (n-1)
            // transport headers per flush, and nothing else — the data
            // bytes are priced identically to n individual sends.
            FwMsg::Batch(inner) => CTRL + wire_size_sum(inner),
            _ => CTRL,
        }
    }
}

// ===================================================== control batching

/// Control-plane batching knobs (DESIGN.md §12), shared by the master,
/// the sub-schedulers and the workers.
#[derive(Debug, Clone, Copy)]
pub struct CtrlBatchCfg {
    /// Master switch (config knob `ctrl_batching`).  Off = every control
    /// message is sent individually and the master handles one message
    /// per receive — exactly the PR 5 control plane, pinned by
    /// `prop_ctrl_batching_off_is_pr5`.
    pub enabled: bool,
    /// Flush a destination's buffer once it holds this many messages
    /// (config knob `ctrl_batch_max_msgs`).
    pub max_msgs: usize,
    /// Flush everything once the oldest buffered message has waited this
    /// long (config knob `ctrl_batch_max_delay_us`).  Bounds the latency a
    /// message can accrue *inside* one long event-loop pass; the loops
    /// additionally flush at every pass boundary, before blocking.
    pub max_delay: Duration,
}

impl Default for CtrlBatchCfg {
    fn default() -> Self {
        CtrlBatchCfg {
            enabled: true,
            max_msgs: 64,
            max_delay: Duration::from_micros(200),
        }
    }
}

/// Per-destination control-message coalescer (DESIGN.md §12).
///
/// Buffers same-destination control messages and ships each destination's
/// run as one [`FwMsg::Batch`] frame, on three triggers: a destination
/// buffer reaching `max_msgs` (count), the oldest buffered message
/// exceeding `max_delay` (delay), and the owning event loop finishing a
/// pass ([`Self::flush_all`] before it blocks — the immediate-barrier
/// trigger).  Messages that need an error-checked immediate send go
/// through [`Self::send_now`], which flushes the destination's buffer
/// first — so **every** path preserves per-destination FIFO order and the
/// §10 `CachePush`-before-`Exec` invariant survives batching.
///
/// With `enabled` off, [`Self::send`] degenerates to a plain
/// `comm.send(dst, TAG_CTRL, msg)` — byte-for-byte the PR 5 wire.
///
/// Public so the concurrency model checks (`rust/tests/loom_models.rs`,
/// DESIGN.md §13) can drive the real implementation through exhaustive
/// interleavings; user code has no reason to touch it.
pub struct Coalescer {
    cfg: CtrlBatchCfg,
    /// Insertion-ordered per-destination buffers.  A `Vec`, not a map: one
    /// actor talks to a handful of destinations (master + peers + own
    /// workers), and insertion order gives deterministic flush order.
    buf: Vec<(Rank, Vec<FwMsg>)>,
    /// Push time of the oldest still-buffered message (delay trigger).
    oldest: Option<Instant>,
}

impl Coalescer {
    /// Fresh coalescer with empty per-destination buffers.
    pub fn new(cfg: CtrlBatchCfg) -> Self {
        Coalescer { cfg, buf: Vec::new(), oldest: None }
    }

    /// Whether batching is on (the `ctrl_batching` knob).
    pub fn enabled(&self) -> bool {
        self.cfg.enabled
    }

    /// Queue `msg` for `dst` (batching on) or send it immediately
    /// (batching off — the PR 5 path).  Send errors on the buffered path
    /// surface at flush time and are dropped there, matching the
    /// fire-and-forget `let _ = send(...)` call sites this replaces.
    pub fn send(
        &mut self,
        comm: &Comm<FwMsg>,
        metrics: &MetricsCollector,
        dst: Rank,
        msg: FwMsg,
    ) {
        if !self.cfg.enabled {
            let _ = comm.send(dst, TAG_CTRL, msg);
            return;
        }
        let idx = match self.buf.iter().position(|(r, _)| *r == dst) {
            Some(i) => i,
            None => {
                self.buf.push((dst, Vec::new()));
                self.buf.len() - 1
            }
        };
        self.buf[idx].1.push(msg);
        if self.oldest.is_none() {
            self.oldest = Some(Instant::now());
        }
        if self.buf[idx].1.len() >= self.cfg.max_msgs.max(1) {
            self.flush_dst(comm, metrics, dst);
        } else if self
            .oldest
            .is_some_and(|t| t.elapsed() >= self.cfg.max_delay)
        {
            self.flush_all(comm, metrics);
        }
    }

    /// FIFO-preserving immediate send: flush `dst`'s buffer, then send
    /// `msg` directly, returning the transport's verdict (the dispatch and
    /// kept-pull paths need the dead-rank error to trigger recovery).
    pub fn send_now(
        &mut self,
        comm: &Comm<FwMsg>,
        metrics: &MetricsCollector,
        dst: Rank,
        msg: FwMsg,
    ) -> crate::error::Result<()> {
        self.flush_dst(comm, metrics, dst);
        comm.send(dst, TAG_CTRL, msg)
    }

    /// Ship a pre-assembled group as **one** frame right now (the
    /// multi-source `CachePush` push of DESIGN.md §10/§12): flush `dst`
    /// first (FIFO), then send a single `Batch` — or the sole member
    /// unwrapped, or nothing for an empty group.
    pub fn send_group_now(
        &mut self,
        comm: &Comm<FwMsg>,
        metrics: &MetricsCollector,
        dst: Rank,
        mut msgs: Vec<FwMsg>,
    ) -> crate::error::Result<()> {
        self.flush_dst(comm, metrics, dst);
        match msgs.len() {
            0 => Ok(()),
            1 => comm.send(dst, TAG_CTRL, msgs.pop().expect("len checked")),
            n => {
                metrics.ctrl_batch_flushed(n);
                comm.send(dst, TAG_CTRL, FwMsg::Batch(msgs))
            }
        }
    }

    /// Flush one destination's buffer (count trigger / pre-direct-send).
    pub fn flush_dst(&mut self, comm: &Comm<FwMsg>, metrics: &MetricsCollector, dst: Rank) {
        let Some(pos) = self
            .buf
            .iter()
            .position(|(r, v)| *r == dst && !v.is_empty())
        else {
            return;
        };
        let msgs = std::mem::take(&mut self.buf[pos].1);
        Self::ship(comm, metrics, dst, msgs);
        if self.buf.iter().all(|(_, v)| v.is_empty()) {
            self.oldest = None;
        }
    }

    /// Flush every buffered destination, in first-buffered order (the
    /// pass-boundary trigger — called before the event loop blocks).
    pub fn flush_all(&mut self, comm: &Comm<FwMsg>, metrics: &MetricsCollector) {
        if self.oldest.is_none() {
            return; // cheap no-op on every quiet loop pass
        }
        for (dst, msgs) in &mut self.buf {
            if !msgs.is_empty() {
                Self::ship(comm, metrics, *dst, std::mem::take(msgs));
            }
        }
        self.oldest = None;
    }

    fn ship(comm: &Comm<FwMsg>, metrics: &MetricsCollector, dst: Rank, mut msgs: Vec<FwMsg>) {
        if msgs.len() == 1 {
            // A lone message needs no frame — identical to the unbatched
            // wire, so a quiet run pays zero batching overhead.
            let _ = comm.send(dst, TAG_CTRL, msgs.pop().expect("len checked"));
        } else {
            metrics.ctrl_batch_flushed(msgs.len());
            let _ = comm.send(dst, TAG_CTRL, FwMsg::Batch(msgs));
        }
    }
}

// ===================================================== heartbeat detector

/// One monitored peer's liveness state.
#[derive(Debug)]
struct PeerState {
    rank: Rank,
    /// Last time any traffic from the peer was observed.
    last_heard: Instant,
    /// Last time a beat was emitted towards the peer.
    last_beat: Instant,
    /// Consecutive beat intervals with no traffic heard.
    misses: u32,
}

/// What one detector tick decided: which peers to beat, which are lost.
#[derive(Debug, Default)]
pub struct HeartbeatTick {
    /// Peers due a [`FwMsg::Heartbeat`] this tick.
    pub beat: Vec<Rank>,
    /// Peers that exhausted `heartbeat_miss_limit` and are declared lost
    /// (removed from monitoring; recovery is the caller's job).
    pub lost: Vec<Rank>,
    /// Misses charged this tick (metrics key `heartbeat_misses`).
    pub new_misses: u64,
}

/// Deadline-based liveness detector — the master side of the heartbeat
/// protocol (DESIGN.md §14).
///
/// Pure state machine over an injected clock: the master's event loop
/// calls [`Self::note_heard`] for every message it receives (any traffic
/// proves liveness, not just acks) and [`Self::on_tick`] once per loop
/// pass.  A peer that stays silent for `miss_limit` consecutive beat
/// intervals is declared lost, so a hung rank is detected even though a
/// send into it would still succeed.  Clock injection keeps the unit
/// tests sleep-free.
#[derive(Debug)]
pub struct HeartbeatDetector {
    interval: Duration,
    miss_limit: u32,
    peers: Vec<PeerState>,
}

impl HeartbeatDetector {
    /// Monitor `peers`, beating every `interval`; `miss_limit` silent
    /// intervals (≥ 1) declare a peer lost.
    pub fn new(peers: &[Rank], interval: Duration, miss_limit: u32, now: Instant) -> Self {
        HeartbeatDetector {
            interval,
            miss_limit: miss_limit.max(1),
            peers: peers
                .iter()
                .map(|&rank| PeerState {
                    rank,
                    last_heard: now,
                    last_beat: now,
                    misses: 0,
                })
                .collect(),
        }
    }

    /// Record traffic from `rank`: resets its miss counter and deadline.
    pub fn note_heard(&mut self, rank: Rank, now: Instant) {
        if let Some(p) = self.peers.iter_mut().find(|p| p.rank == rank) {
            p.last_heard = now;
            p.misses = 0;
        }
    }

    /// Stop monitoring `rank` (clean shutdown or recovery already ran).
    pub fn remove(&mut self, rank: Rank) {
        self.peers.retain(|p| p.rank != rank);
    }

    /// Ranks currently monitored.
    pub fn monitored(&self) -> Vec<Rank> {
        self.peers.iter().map(|p| p.rank).collect()
    }

    /// Advance the detector to `now`: emit due beats, charge misses for
    /// peers silent a full interval past their last credit, and declare
    /// peers lost at `miss_limit`.  Lost peers are removed from
    /// monitoring (recovery must not be re-triggered every pass).
    pub fn on_tick(&mut self, now: Instant) -> HeartbeatTick {
        let mut tick = HeartbeatTick::default();
        for p in &mut self.peers {
            if now.duration_since(p.last_beat) < self.interval {
                continue;
            }
            p.last_beat = now;
            tick.beat.push(p.rank);
            if now.duration_since(p.last_heard) >= self.interval {
                p.misses += 1;
                tick.new_misses += 1;
                if p.misses >= self.miss_limit {
                    tick.lost.push(p.rank);
                }
            }
        }
        self.peers.retain(|p| !tick.lost.contains(&p.rank));
        // A lost peer needs no farewell beat.
        tick.beat.retain(|r| !tick.lost.contains(r));
        tick
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{CostModel, World};
    use crate::data::DataChunk;

    #[test]
    fn exec_request_counts_only_shipped_bytes() {
        let req = ExecRequest {
            spec: JobSpec::new(1, 1, 1),
            input: vec![
                InputPart::Data(FunctionData::of_f32(vec![0.0; 10])), // 40 B
                InputPart::Kept { job: JobId(2), range: ChunkRange::All }, // 0 B
            ],
        };
        assert_eq!(req.shipped_bytes(), 40);
        assert!(FwMsg::Exec(req).wire_size() >= 40);
    }

    #[test]
    fn result_data_wire_size_scales() {
        let small = FwMsg::ResultData {
            job: JobId(1),
            data: FunctionData::of_f32(vec![0.0; 1]),
        };
        let big = FwMsg::ResultData {
            job: JobId(1),
            data: FunctionData::from_chunks(vec![DataChunk::from_f32(vec![0.0; 1000])]),
        };
        assert!(big.wire_size() > small.wire_size() + 3000);
    }

    #[test]
    fn batch_wire_size_is_ctrl_plus_sum_of_inner() {
        let inner = vec![
            FwMsg::JobDone {
                job: JobId(1),
                kept_on: None,
                output_bytes: 0,
                chunks: 0,
                injections: vec![],
                exec_us: 5,
            },
            FwMsg::ReleaseResult { job: JobId(2) },
            FwMsg::ResultData {
                job: JobId(3),
                data: FunctionData::of_f32(vec![0.0; 10]),
            },
        ];
        let sum: usize = inner.iter().map(|m| m.wire_size()).sum();
        assert_eq!(FwMsg::Batch(inner).wire_size(), 32 + sum);
        assert_eq!(FwMsg::Batch(Vec::new()).wire_size(), 32);
    }

    #[test]
    fn assign_and_prefetch_charge_sources_at_the_same_rate() {
        // Satellite of DESIGN.md §12: a per-source location hint must cost
        // the same whether it rides an Assign or a Prefetch, so moving
        // hints between the two (as coalescing does) never skews the α/β
        // calibration.
        let src = |j: u32| SourceLoc { job: JobId(j), owner: Rank(1), kept_on: None };
        let assign = |n: u32| FwMsg::Assign {
            spec: JobSpec::new(9, 1, 1),
            sources: (0..n).map(src).collect(),
        };
        let prefetch = |n: u32| FwMsg::Prefetch {
            job: JobId(9),
            threads: ThreadCount::Exact(1),
            sources: (0..n).map(src).collect(),
        };
        let da = assign(4).wire_size() - assign(1).wire_size();
        let dp = prefetch(4).wire_size() - prefetch(1).wire_size();
        assert_eq!(da, dp, "per-source hint rate differs between Assign and Prefetch");
        assert_eq!(dp, 3 * SRC_LOC_BYTES);
    }

    #[test]
    fn coalescer_off_sends_each_message_immediately_and_unbatched() {
        let world: World<FwMsg> = World::new(CostModel::free());
        let a = world.add_rank();
        let mut b = world.add_rank();
        let metrics = MetricsCollector::new();
        let mut coal =
            Coalescer::new(CtrlBatchCfg { enabled: false, ..Default::default() });
        for j in 0..3 {
            coal.send(&a, &metrics, b.rank(), FwMsg::ReleaseResult { job: JobId(j) });
        }
        for j in 0..3 {
            let env = b.try_recv().unwrap().expect("off-knob sends are immediate");
            assert!(
                matches!(env.into_user(), FwMsg::ReleaseResult { job } if job == JobId(j)),
                "off-knob wire must be the plain PR 5 message sequence"
            );
        }
        assert!(b.try_recv().unwrap().is_none());
    }

    #[test]
    fn coalescer_flushes_one_frame_per_destination_preserving_fifo() {
        let world: World<FwMsg> = World::new(CostModel::free());
        let a = world.add_rank();
        let mut b = world.add_rank();
        let mut c = world.add_rank();
        let metrics = MetricsCollector::new();
        let mut coal = Coalescer::new(CtrlBatchCfg::default());
        for j in 0..3 {
            coal.send(&a, &metrics, b.rank(), FwMsg::ReleaseResult { job: JobId(j) });
        }
        coal.send(&a, &metrics, c.rank(), FwMsg::ReleaseResult { job: JobId(7) });
        coal.send(&a, &metrics, c.rank(), FwMsg::ReleaseResult { job: JobId(8) });
        // Nothing on the wire before the pass-boundary flush.
        assert!(b.try_recv().unwrap().is_none());
        coal.flush_all(&a, &metrics);
        let env = b.try_recv().unwrap().expect("one frame for b");
        match env.into_user() {
            FwMsg::Batch(msgs) => {
                let jobs: Vec<u32> = msgs
                    .iter()
                    .map(|m| match m {
                        FwMsg::ReleaseResult { job } => job.0,
                        other => panic!("unexpected member {other:?}"),
                    })
                    .collect();
                assert_eq!(jobs, vec![0, 1, 2], "members must keep send order");
            }
            other => panic!("expected Batch, got {other:?}"),
        }
        assert!(b.try_recv().unwrap().is_none(), "exactly one send to b");
        assert!(matches!(
            c.try_recv().unwrap().expect("one frame for c").into_user(),
            FwMsg::Batch(msgs) if msgs.len() == 2
        ));
        let snap = metrics
            .finish(crate::comm::StatsSnapshot { msgs: 0, bytes: 0, modelled_comm_ns: 0 });
        assert_eq!(snap.ctrl_batches, 2);
        assert_eq!(snap.ctrl_msgs_coalesced, 5);
        assert_eq!(snap.ctrl_batch_max, 3);
    }

    #[test]
    fn coalescer_count_trigger_and_send_now_keep_fifo() {
        let world: World<FwMsg> = World::new(CostModel::free());
        let a = world.add_rank();
        let mut b = world.add_rank();
        let metrics = MetricsCollector::new();
        let mut coal = Coalescer::new(CtrlBatchCfg {
            enabled: true,
            max_msgs: 2,
            max_delay: Duration::from_secs(3600),
        });
        // Count trigger: the second push flushes a 2-frame.
        coal.send(&a, &metrics, b.rank(), FwMsg::ReleaseResult { job: JobId(1) });
        coal.send(&a, &metrics, b.rank(), FwMsg::ReleaseResult { job: JobId(2) });
        // Buffer one more, then an immediate send must drain it first.
        coal.send(&a, &metrics, b.rank(), FwMsg::ReleaseResult { job: JobId(3) });
        coal.send_now(&a, &metrics, b.rank(), FwMsg::Shutdown).unwrap();
        let mut seen: Vec<FwMsg> = Vec::new();
        while let Some(env) = b.try_recv().unwrap() {
            match env.into_user() {
                FwMsg::Batch(msgs) => seen.extend(msgs),
                m => seen.push(m),
            }
        }
        let order: Vec<String> = seen.iter().map(|m| format!("{m:?}")).collect();
        assert!(
            matches!(seen[0], FwMsg::ReleaseResult { job } if job == JobId(1)),
            "{order:?}"
        );
        assert!(matches!(seen[1], FwMsg::ReleaseResult { job } if job == JobId(2)));
        assert!(
            matches!(seen[2], FwMsg::ReleaseResult { job } if job == JobId(3)),
            "send_now must flush the destination buffer first: {order:?}"
        );
        assert!(matches!(seen[3], FwMsg::Shutdown));
        // A lone buffered message ships unwrapped (no 1-element frames).
        coal.send(&a, &metrics, b.rank(), FwMsg::ReleaseResult { job: JobId(9) });
        coal.flush_all(&a, &metrics);
        assert!(matches!(
            b.try_recv().unwrap().expect("flushed").into_user(),
            FwMsg::ReleaseResult { job } if job == JobId(9)
        ));
    }

    const HB: Duration = Duration::from_millis(100);

    #[test]
    fn heartbeat_detector_declares_loss_at_miss_limit() {
        let t0 = Instant::now();
        let mut det = HeartbeatDetector::new(&[Rank(1), Rank(2)], HB, 3, t0);
        // Rank 2 stays chatty; rank 1 goes silent after t0.
        let mut lost = Vec::new();
        for k in 1..=4u32 {
            let now = t0 + HB * k;
            det.note_heard(Rank(2), now);
            let tick = det.on_tick(now);
            lost.extend(tick.lost);
        }
        assert_eq!(lost, vec![Rank(1)], "silent rank must be lost after 3 misses");
        assert_eq!(det.monitored(), vec![Rank(2)], "lost rank leaves monitoring");
        // No re-detection on later ticks.
        assert!(det.on_tick(t0 + HB * 10).lost.is_empty());
    }

    #[test]
    fn heartbeat_ack_resets_miss_counter() {
        let t0 = Instant::now();
        let mut det = HeartbeatDetector::new(&[Rank(1)], HB, 2, t0);
        assert_eq!(det.on_tick(t0 + HB).new_misses, 1);
        // An ack just before the second deadline wipes the count…
        det.note_heard(Rank(1), t0 + HB + HB / 2);
        let tick = det.on_tick(t0 + HB * 2);
        assert!(tick.lost.is_empty(), "reset counter must not reach the limit");
        // …and the peer survives as long as acks keep arriving.
        for k in 3..8u32 {
            det.note_heard(Rank(1), t0 + HB * k - HB / 2);
            assert!(det.on_tick(t0 + HB * k).lost.is_empty());
        }
        assert_eq!(det.monitored(), vec![Rank(1)]);
    }

    #[test]
    fn heartbeat_detects_hung_rank_without_any_send() {
        // The wire never fails: the peer is registered, sends to it
        // succeed — it just never answers.  Only the deadline notices.
        let t0 = Instant::now();
        let mut det = HeartbeatDetector::new(&[Rank(1)], HB, 2, t0);
        let t1 = det.on_tick(t0 + HB);
        assert_eq!(t1.beat, vec![Rank(1)], "idle link still gets probed");
        assert!(t1.lost.is_empty());
        let t2 = det.on_tick(t0 + HB * 2);
        assert_eq!(t2.lost, vec![Rank(1)]);
        assert!(t2.beat.is_empty(), "no farewell beat for a lost rank");
        assert!(det.monitored().is_empty());
    }

    #[test]
    fn heartbeat_beats_are_paced_by_interval() {
        let t0 = Instant::now();
        let mut det = HeartbeatDetector::new(&[Rank(1)], HB, 100, t0);
        assert!(det.on_tick(t0 + HB / 2).beat.is_empty(), "too early to beat");
        assert_eq!(det.on_tick(t0 + HB).beat, vec![Rank(1)]);
        assert!(
            det.on_tick(t0 + HB + HB / 2).beat.is_empty(),
            "beat cadence restarts from the last beat"
        );
    }
}
