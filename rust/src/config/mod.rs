//! Topology and engine configuration (JSON file + programmatic builder).
//!
//! A config describes the simulated cluster the framework runs on: how many
//! sub-schedulers, how many workers each may spawn, how many cores a worker
//! "node" has (the packing budget for multi-threaded jobs), the comm cost
//! model, and where the AOT compute artifacts live.
//!
//! File format is JSON (parsed by [`crate::util::json`]); every field is
//! optional and falls back to the default:
//!
//! ```json
//! {
//!   "schedulers": 2,
//!   "workers_per_scheduler": 4,
//!   "cores_per_worker": 4,
//!   "prespawn_workers": false,
//!   "fault_timeout_ms": 5000,
//!   "comm_cost_model": {"alpha_us": 2.0, "bandwidth_gbps": 10.0, "simulate": false},
//!   "engine": {"artifact_dir": "artifacts", "variant": "ref"},
//!   "execution_mode": "dataflow",
//!   "transport": "inproc",
//!   "speculative_prefetch": true,
//!   "work_stealing": true,
//!   "steal_granularity": 1,
//!   "cost_model": true,
//!   "cost_ewma_alpha": 0.3,
//!   "comm_aware_placement": true,
//!   "comm_calibration": true,
//!   "comm_calibration_ewma_alpha": 0.3,
//!   "ctrl_batching": true,
//!   "ctrl_batch_max_msgs": 64,
//!   "ctrl_batch_max_delay_us": 200,
//!   "heartbeats": true,
//!   "heartbeat_interval_ms": 200,
//!   "heartbeat_miss_limit": 15,
//!   "straggler_deadlines": true,
//!   "straggler_factor": 16.0,
//!   "straggler_cold_us": 2000000,
//!   "max_rank_losses": 4,
//!   "job_retry_backoff_us": 250000,
//!   "memory_budget_bytes": 0,
//!   "spill_dir": null,
//!   "eviction_policy": "cost-aware-lru"
//! }
//! ```
//!
//! The canonical description of every knob — JSON key, builder method,
//! default and effect — is the config-knob table in the repository
//! `README.md`; its "Which knobs for which workload" section maps
//! workload shapes (compute-skewed, transfer-heavy, paper-faithful) to
//! knob combinations.
//!
//! Compatibility: `cost_model` used to be the name of the *communication*
//! cost-model section (now `comm_cost_model`); an object under the
//! `cost_model` key is still parsed as the comm model, while a boolean is
//! the scheduling knob.

use std::path::{Path, PathBuf};

use crate::comm::{CostModel, TransportKind};
use crate::data::EvictionPolicy;
use crate::error::{Error, Result};
use crate::util::json::{self, Json};

/// Communication cost-model section (the α/β latency-bandwidth model of
/// [`crate::comm`]; JSON key `comm_cost_model`).  Unrelated to the
/// *execution* cost model of DESIGN.md §9 (knobs `cost_model` /
/// `cost_ewma_alpha`).
#[derive(Debug, Clone)]
pub struct CostModelConfig {
    /// Per-message latency in microseconds (the α term).
    pub alpha_us: f64,
    /// Link bandwidth in Gbit/s (the β term).
    pub bandwidth_gbps: f64,
    /// Inject the modelled delay into real sends (benchmarking aid).
    pub simulate: bool,
}

impl Default for CostModelConfig {
    fn default() -> Self {
        let m = CostModel::default();
        CostModelConfig {
            alpha_us: m.alpha_us,
            bandwidth_gbps: m.bandwidth_gbps,
            simulate: m.simulate,
        }
    }
}

impl From<CostModelConfig> for CostModel {
    fn from(c: CostModelConfig) -> CostModel {
        CostModel {
            alpha_us: c.alpha_us,
            bandwidth_gbps: c.bandwidth_gbps,
            simulate: c.simulate,
        }
    }
}

/// Compute-engine section: where artifacts live and which kernel variant
/// user functions resolve by default.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Directory containing `manifest.json` + `*.hlo.txt`.
    pub artifact_dir: PathBuf,
    /// `"pallas"` (the L1 kernels) or `"ref"` (pure-jnp lowering).
    pub variant: String,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { artifact_dir: PathBuf::from("artifacts"), variant: "ref".into() }
    }
}

/// How the master releases work to the cluster (DESIGN.md §7).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecutionMode {
    /// Segment-barrier execution (the paper's literal model): every job of
    /// segment *k* completes before any job of segment *k+1* is assigned.
    /// Pick this for workloads with genuine per-segment side effects, for
    /// apples-to-apples comparison against the paper, or when debugging —
    /// the schedule is easier to reason about.
    Barrier,
    /// Dependency-DAG execution: a job is assigned the moment every result
    /// it references is available, across segment boundaries.  Stragglers
    /// stall only their own dependents, so computation and communication
    /// of independent lanes overlap.  The default.
    #[default]
    Dataflow,
}

impl ExecutionMode {
    /// The JSON string form of this mode.
    pub fn as_str(self) -> &'static str {
        match self {
            ExecutionMode::Barrier => "barrier",
            ExecutionMode::Dataflow => "dataflow",
        }
    }

    /// Parse the JSON string form (`"barrier"` / `"dataflow"`).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "barrier" => Ok(ExecutionMode::Barrier),
            "dataflow" => Ok(ExecutionMode::Dataflow),
            other => Err(Error::Config(format!(
                "execution_mode must be \"barrier\" or \"dataflow\", got {other:?}"
            ))),
        }
    }
}

impl std::fmt::Display for ExecutionMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Full topology configuration.
#[derive(Debug, Clone)]
pub struct TopologyConfig {
    /// Number of sub-schedulers (paper: fixed for the whole run, >= 1).
    pub schedulers: usize,
    /// Upper bound of workers each sub-scheduler may spawn.
    pub workers_per_scheduler: usize,
    /// Cores per worker "node" — the packing budget for thread counts
    /// (paper §3.3: two 2-thread jobs share one 4-core worker).
    pub cores_per_worker: usize,
    /// Spawn workers eagerly at startup instead of on demand.
    pub prespawn_workers: bool,
    /// Worker-loss detection timeout in milliseconds.
    pub fault_timeout_ms: u64,
    /// Communication α/β cost model (JSON key `comm_cost_model`).
    pub comm_cost_model: CostModelConfig,
    /// Optional compute engine (absent = pure-rust user functions only).
    pub engine: Option<EngineConfig>,
    /// Barrier vs dataflow control plane (DESIGN.md §7).
    pub execution_mode: ExecutionMode,
    /// Which substrate carries cross-rank messages (DESIGN.md §15):
    /// `"inproc"` (default — in-process mailboxes, the historical
    /// behaviour bit-for-bit) or `"tcp"` (loopback sockets with
    /// length-prefixed wire framing; same values, real serialisation).
    /// The `HYPAR_TRANSPORT` environment variable overrides this knob at
    /// run time so an unchanged test suite can exercise either backend.
    pub transport: TransportKind,
    /// Speculative input prefetch under dataflow execution (DESIGN.md §7):
    /// when a waiting job has all inputs but one materialised, its probable
    /// target scheduler pulls the remote ones while the last producer
    /// still runs.  On by default; purely a transfer/latency trade — never
    /// affects computed values.
    pub speculative_prefetch: bool,
    /// Chunk-granular work stealing on the worker sequence pool
    /// (DESIGN.md §8).  On by default; off disables stealing (pair with
    /// `cost_model: false` for the paper's fully static round-robin
    /// split).  Byte-identical results either way — only where and when
    /// chunks execute changes.
    pub work_stealing: bool,
    /// Chunks taken per steal operation (>= 1).  1 = finest-grained
    /// balancing; larger values amortise deque locking for tiny chunks.
    /// Ignored while `cost_model` is on (the steal amount adapts).
    pub steal_granularity: usize,
    /// Feedback-driven cost-model scheduling (DESIGN.md §9): measure
    /// per-chunk and per-job execution costs and use them to pre-balance
    /// the chunk deal (LPT), size steals by estimated cost, and break
    /// placement ties by estimated outstanding cost.  On by default; off
    /// reverts every decision to the static policies.  Values are
    /// byte-identical either way.
    pub cost_model: bool,
    /// EWMA smoothing factor for the execution cost tables (weight of the
    /// newest observation, `(0, 1]`).
    pub cost_ewma_alpha: f64,
    /// Comm-aware placement (DESIGN.md §10): the master prices candidate
    /// targets by estimated compute backlog **plus** modelled transfer
    /// time (per-peer calibrated α/β), sizes job estimates per input byte,
    /// and kept-result prefetch warms predicted worker caches.  On by
    /// default; off reproduces the PR 4 byte-affinity placement exactly.
    /// Values are byte-identical either way — only where jobs run and
    /// when bytes move changes.  See the README tuning guide for which
    /// workloads benefit.
    pub comm_aware_placement: bool,
    /// Refine the configured comm α/β per peer from observed transfer
    /// times (DESIGN.md §10).  Off = placement always prices with the
    /// configured `comm_cost_model` values.
    pub comm_calibration: bool,
    /// EWMA smoothing factor of the per-peer link calibration (weight of
    /// the newest observed transfer, `(0, 1]`).
    pub comm_calibration_ewma_alpha: f64,
    /// Control-plane message coalescing + amortised master passes
    /// (DESIGN.md §12): subs and workers buffer same-destination control
    /// messages into `FwMsg::Batch` frames, and the master drains its
    /// whole mailbox per scheduling pass.  On by default; off reproduces
    /// the PR 5 one-message-one-pass control plane exactly (pinned by
    /// property test).  Values are byte-identical either way.
    pub ctrl_batching: bool,
    /// Most control messages a coalescer buffers per destination before
    /// flushing a frame (>= 1).  Larger batches amortise more per-message
    /// overhead at the cost of dispatch latency.
    pub ctrl_batch_max_msgs: usize,
    /// Longest a buffered control message may wait before a flush is
    /// forced, in microseconds (latency bound of the coalescers).
    pub ctrl_batch_max_delay_us: u64,
    /// Master↔sub heartbeat failure detection (DESIGN.md §14): the master
    /// beats every monitored sub and declares a rank lost after
    /// `heartbeat_miss_limit` silent intervals, catching *hung* ranks the
    /// fail-fast sends cannot see.  On by default; off reproduces the
    /// PR 7 control plane exactly (pinned by property test).
    pub heartbeats: bool,
    /// Heartbeat probe cadence in milliseconds (>= 1).  The detection
    /// deadline is roughly `heartbeat_interval_ms × heartbeat_miss_limit`.
    pub heartbeat_interval_ms: u64,
    /// Consecutive silent heartbeat intervals before a rank is declared
    /// lost (>= 1).
    pub heartbeat_miss_limit: u32,
    /// Deadline-based straggler re-execution (DESIGN.md §14): jobs whose
    /// execution exceeds the §9 cost-model estimate by `straggler_factor`
    /// are speculatively re-placed on another scheduler; first completion
    /// wins, the loser is cancelled.  On by default; off reproduces the
    /// PR 7 scheduling exactly (pinned by property test).  Values are
    /// byte-identical either way.
    pub straggler_deadlines: bool,
    /// Deadline multiplier over the cost-model estimate (>= 1).  Large
    /// values only catch pathological stalls; small values trade
    /// redundant work for latency.
    pub straggler_factor: f64,
    /// Deadline floor in microseconds, used while a job kind has no
    /// estimate yet (cold start) and as the minimum deadline always.
    pub straggler_cold_us: u64,
    /// Rank losses tolerated before the run degrades gracefully
    /// (DESIGN.md §14): one more loss fails the run with a structured
    /// `Error::Degraded` report instead of recovering forever.
    pub max_rank_losses: usize,
    /// Minimum spacing between speculative re-executions of the same job,
    /// in microseconds (backoff of the straggler re-placement loop).
    pub job_retry_backoff_us: u64,
    /// Per-rank store byte budget (DESIGN.md §16): every sub-scheduler
    /// result store and worker kept cache charges its resident entries
    /// against this many bytes and evicts when over.  0 (the default)
    /// disables budgeting — today's unbounded behaviour bit-for-bit.
    pub memory_budget_bytes: u64,
    /// Directory for spill files backing owned-result and kept-cache
    /// eviction (DESIGN.md §16).  Unset (the default / JSON `null`)
    /// disables spilling, leaving only re-fetchable transient copies
    /// evictable.
    pub spill_dir: Option<PathBuf>,
    /// Victim ordering of budgeted stores (DESIGN.md §16):
    /// `"cost-aware-lru"` (the default, score = bytes × age ÷ estimated
    /// recompute µs) or `"lru"` (plain recency).
    pub eviction_policy: EvictionPolicy,
}

impl Default for TopologyConfig {
    fn default() -> Self {
        TopologyConfig {
            schedulers: 2,
            workers_per_scheduler: 4,
            cores_per_worker: 4,
            prespawn_workers: false,
            fault_timeout_ms: 5_000,
            comm_cost_model: CostModelConfig::default(),
            engine: None,
            execution_mode: ExecutionMode::default(),
            transport: TransportKind::default(),
            speculative_prefetch: true,
            work_stealing: true,
            steal_granularity: 1,
            cost_model: true,
            cost_ewma_alpha: crate::cost::DEFAULT_COST_EWMA_ALPHA,
            comm_aware_placement: true,
            comm_calibration: true,
            comm_calibration_ewma_alpha: crate::comm::costmodel::DEFAULT_CALIBRATION_EWMA_ALPHA,
            ctrl_batching: true,
            ctrl_batch_max_msgs: 64,
            ctrl_batch_max_delay_us: 200,
            heartbeats: true,
            heartbeat_interval_ms: 200,
            heartbeat_miss_limit: 15,
            straggler_deadlines: true,
            straggler_factor: 16.0,
            straggler_cold_us: 2_000_000,
            max_rank_losses: 4,
            job_retry_backoff_us: 250_000,
            memory_budget_bytes: 0,
            spill_dir: None,
            eviction_policy: EvictionPolicy::default(),
        }
    }
}

impl TopologyConfig {
    /// Load from a JSON file (missing fields default).
    pub fn from_json_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())?;
        let cfg = Self::from_json_text(&text)?;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Parse a JSON config document (missing fields default).
    pub fn from_json_text(text: &str) -> Result<Self> {
        let doc = json::parse(text).map_err(|e| Error::Config(e.to_string()))?;
        let mut cfg = TopologyConfig::default();
        let get_usize = |key: &str, dflt: usize| -> Result<usize> {
            match doc.get(key) {
                None => Ok(dflt),
                Some(v) => v
                    .as_usize()
                    .ok_or_else(|| Error::Config(format!("{key} must be an integer"))),
            }
        };
        cfg.schedulers = get_usize("schedulers", cfg.schedulers)?;
        cfg.workers_per_scheduler =
            get_usize("workers_per_scheduler", cfg.workers_per_scheduler)?;
        cfg.cores_per_worker = get_usize("cores_per_worker", cfg.cores_per_worker)?;
        cfg.fault_timeout_ms = get_usize("fault_timeout_ms", cfg.fault_timeout_ms as usize)? as u64;
        if let Some(v) = doc.get("prespawn_workers") {
            cfg.prespawn_workers = v
                .as_bool()
                .ok_or_else(|| Error::Config("prespawn_workers must be a bool".into()))?;
        }
        // The comm model's canonical key, plus the pre-rename `cost_model`
        // object form for compatibility (a *boolean* `cost_model` is the
        // scheduling knob, handled below).
        // Legacy form first so the canonical key wins when both appear.
        for key in ["cost_model", "comm_cost_model"] {
            let Some(cm) = doc.get(key) else { continue };
            if !matches!(cm, Json::Obj(_)) {
                continue;
            }
            if let Some(v) = cm.get("alpha_us").and_then(Json::as_f64) {
                cfg.comm_cost_model.alpha_us = v;
            }
            if let Some(v) = cm.get("bandwidth_gbps").and_then(Json::as_f64) {
                cfg.comm_cost_model.bandwidth_gbps = v;
            }
            if let Some(v) = cm.get("simulate").and_then(Json::as_bool) {
                cfg.comm_cost_model.simulate = v;
            }
        }
        match doc.get("cost_model") {
            None | Some(Json::Obj(_)) => {} // absent, or the legacy comm form
            Some(Json::Bool(b)) => cfg.cost_model = *b,
            Some(_) => {
                return Err(Error::Config(
                    "cost_model must be a bool (scheduling knob) or an object \
                     (legacy comm cost model)"
                        .into(),
                ))
            }
        }
        if let Some(v) = doc.get("cost_ewma_alpha") {
            cfg.cost_ewma_alpha = v
                .as_f64()
                .ok_or_else(|| Error::Config("cost_ewma_alpha must be a number".into()))?;
        }
        if let Some(v) = doc.get("comm_aware_placement") {
            cfg.comm_aware_placement = v.as_bool().ok_or_else(|| {
                Error::Config("comm_aware_placement must be a bool".into())
            })?;
        }
        if let Some(v) = doc.get("comm_calibration") {
            cfg.comm_calibration = v
                .as_bool()
                .ok_or_else(|| Error::Config("comm_calibration must be a bool".into()))?;
        }
        if let Some(v) = doc.get("comm_calibration_ewma_alpha") {
            cfg.comm_calibration_ewma_alpha = v.as_f64().ok_or_else(|| {
                Error::Config("comm_calibration_ewma_alpha must be a number".into())
            })?;
        }
        if let Some(v) = doc.get("ctrl_batching") {
            cfg.ctrl_batching = v
                .as_bool()
                .ok_or_else(|| Error::Config("ctrl_batching must be a bool".into()))?;
        }
        cfg.ctrl_batch_max_msgs =
            get_usize("ctrl_batch_max_msgs", cfg.ctrl_batch_max_msgs)?;
        cfg.ctrl_batch_max_delay_us =
            get_usize("ctrl_batch_max_delay_us", cfg.ctrl_batch_max_delay_us as usize)?
                as u64;
        if let Some(v) = doc.get("heartbeats") {
            cfg.heartbeats = v
                .as_bool()
                .ok_or_else(|| Error::Config("heartbeats must be a bool".into()))?;
        }
        cfg.heartbeat_interval_ms =
            get_usize("heartbeat_interval_ms", cfg.heartbeat_interval_ms as usize)? as u64;
        cfg.heartbeat_miss_limit =
            get_usize("heartbeat_miss_limit", cfg.heartbeat_miss_limit as usize)? as u32;
        if let Some(v) = doc.get("straggler_deadlines") {
            cfg.straggler_deadlines = v.as_bool().ok_or_else(|| {
                Error::Config("straggler_deadlines must be a bool".into())
            })?;
        }
        if let Some(v) = doc.get("straggler_factor") {
            cfg.straggler_factor = v
                .as_f64()
                .ok_or_else(|| Error::Config("straggler_factor must be a number".into()))?;
        }
        cfg.straggler_cold_us =
            get_usize("straggler_cold_us", cfg.straggler_cold_us as usize)? as u64;
        cfg.max_rank_losses = get_usize("max_rank_losses", cfg.max_rank_losses)?;
        cfg.job_retry_backoff_us =
            get_usize("job_retry_backoff_us", cfg.job_retry_backoff_us as usize)? as u64;
        cfg.memory_budget_bytes =
            get_usize("memory_budget_bytes", cfg.memory_budget_bytes as usize)? as u64;
        if let Some(v) = doc.get("spill_dir") {
            if *v != Json::Null {
                let s = v
                    .as_str()
                    .ok_or_else(|| Error::Config("spill_dir must be a string".into()))?;
                cfg.spill_dir = Some(PathBuf::from(s));
            }
        }
        if let Some(v) = doc.get("eviction_policy") {
            let s = v
                .as_str()
                .ok_or_else(|| Error::Config("eviction_policy must be a string".into()))?;
            cfg.eviction_policy = EvictionPolicy::parse(s)?;
        }
        if let Some(v) = doc.get("execution_mode") {
            let s = v
                .as_str()
                .ok_or_else(|| Error::Config("execution_mode must be a string".into()))?;
            cfg.execution_mode = ExecutionMode::parse(s)?;
        }
        if let Some(v) = doc.get("transport") {
            let s = v
                .as_str()
                .ok_or_else(|| Error::Config("transport must be a string".into()))?;
            cfg.transport = TransportKind::parse(s)?;
        }
        if let Some(v) = doc.get("speculative_prefetch") {
            cfg.speculative_prefetch = v.as_bool().ok_or_else(|| {
                Error::Config("speculative_prefetch must be a bool".into())
            })?;
        }
        if let Some(v) = doc.get("work_stealing") {
            cfg.work_stealing = v
                .as_bool()
                .ok_or_else(|| Error::Config("work_stealing must be a bool".into()))?;
        }
        cfg.steal_granularity = get_usize("steal_granularity", cfg.steal_granularity)?;
        if let Some(e) = doc.get("engine") {
            if *e != Json::Null {
                let dir = e
                    .get("artifact_dir")
                    .and_then(Json::as_str)
                    .unwrap_or("artifacts");
                let variant = e.get("variant").and_then(Json::as_str).unwrap_or("ref");
                cfg.engine = Some(EngineConfig {
                    artifact_dir: PathBuf::from(dir),
                    variant: variant.to_string(),
                });
            }
        }
        Ok(cfg)
    }

    /// Serialise to pretty JSON (for `hypar config --dump`).
    pub fn to_json(&self) -> String {
        let mut entries = vec![
            ("schedulers", Json::num(self.schedulers as f64)),
            (
                "workers_per_scheduler",
                Json::num(self.workers_per_scheduler as f64),
            ),
            ("cores_per_worker", Json::num(self.cores_per_worker as f64)),
            ("prespawn_workers", Json::Bool(self.prespawn_workers)),
            ("fault_timeout_ms", Json::num(self.fault_timeout_ms as f64)),
            (
                "execution_mode",
                Json::str(self.execution_mode.as_str().to_string()),
            ),
            ("transport", Json::str(self.transport.as_str().to_string())),
            ("speculative_prefetch", Json::Bool(self.speculative_prefetch)),
            ("work_stealing", Json::Bool(self.work_stealing)),
            (
                "steal_granularity",
                Json::num(self.steal_granularity as f64),
            ),
            ("cost_model", Json::Bool(self.cost_model)),
            ("cost_ewma_alpha", Json::num(self.cost_ewma_alpha)),
            (
                "comm_aware_placement",
                Json::Bool(self.comm_aware_placement),
            ),
            ("comm_calibration", Json::Bool(self.comm_calibration)),
            (
                "comm_calibration_ewma_alpha",
                Json::num(self.comm_calibration_ewma_alpha),
            ),
            ("ctrl_batching", Json::Bool(self.ctrl_batching)),
            (
                "ctrl_batch_max_msgs",
                Json::num(self.ctrl_batch_max_msgs as f64),
            ),
            (
                "ctrl_batch_max_delay_us",
                Json::num(self.ctrl_batch_max_delay_us as f64),
            ),
            ("heartbeats", Json::Bool(self.heartbeats)),
            (
                "heartbeat_interval_ms",
                Json::num(self.heartbeat_interval_ms as f64),
            ),
            (
                "heartbeat_miss_limit",
                Json::num(self.heartbeat_miss_limit as f64),
            ),
            ("straggler_deadlines", Json::Bool(self.straggler_deadlines)),
            ("straggler_factor", Json::num(self.straggler_factor)),
            (
                "straggler_cold_us",
                Json::num(self.straggler_cold_us as f64),
            ),
            ("max_rank_losses", Json::num(self.max_rank_losses as f64)),
            (
                "job_retry_backoff_us",
                Json::num(self.job_retry_backoff_us as f64),
            ),
            (
                "memory_budget_bytes",
                Json::num(self.memory_budget_bytes as f64),
            ),
            (
                "spill_dir",
                match &self.spill_dir {
                    Some(p) => Json::str(p.to_string_lossy().to_string()),
                    None => Json::Null,
                },
            ),
            (
                "eviction_policy",
                Json::str(self.eviction_policy.as_str().to_string()),
            ),
            (
                "comm_cost_model",
                Json::obj(vec![
                    ("alpha_us", Json::num(self.comm_cost_model.alpha_us)),
                    (
                        "bandwidth_gbps",
                        Json::num(self.comm_cost_model.bandwidth_gbps),
                    ),
                    ("simulate", Json::Bool(self.comm_cost_model.simulate)),
                ]),
            ),
        ];
        if let Some(e) = &self.engine {
            entries.push((
                "engine",
                Json::obj(vec![
                    (
                        "artifact_dir",
                        Json::str(e.artifact_dir.to_string_lossy().to_string()),
                    ),
                    ("variant", Json::str(e.variant.clone())),
                ]),
            ));
        }
        Json::obj(entries).to_string_pretty(2)
    }

    /// Check invariants (counts >= 1, knob ranges, engine variant).
    pub fn validate(&self) -> Result<()> {
        if self.schedulers == 0 {
            return Err(Error::Config("schedulers must be >= 1".into()));
        }
        if self.workers_per_scheduler == 0 {
            return Err(Error::Config("workers_per_scheduler must be >= 1".into()));
        }
        if self.cores_per_worker == 0 {
            return Err(Error::Config("cores_per_worker must be >= 1".into()));
        }
        if self.steal_granularity == 0 {
            return Err(Error::Config("steal_granularity must be >= 1".into()));
        }
        if self.ctrl_batch_max_msgs == 0 {
            return Err(Error::Config("ctrl_batch_max_msgs must be >= 1".into()));
        }
        if self.heartbeat_interval_ms == 0 {
            return Err(Error::Config("heartbeat_interval_ms must be >= 1".into()));
        }
        if self.heartbeat_miss_limit == 0 {
            return Err(Error::Config("heartbeat_miss_limit must be >= 1".into()));
        }
        if !self.straggler_factor.is_finite() || self.straggler_factor < 1.0 {
            return Err(Error::Config(format!(
                "straggler_factor must be >= 1, got {}",
                self.straggler_factor
            )));
        }
        if !self.cost_ewma_alpha.is_finite()
            || self.cost_ewma_alpha <= 0.0
            || self.cost_ewma_alpha > 1.0
        {
            return Err(Error::Config(format!(
                "cost_ewma_alpha must be in (0, 1], got {}",
                self.cost_ewma_alpha
            )));
        }
        if !self.comm_calibration_ewma_alpha.is_finite()
            || self.comm_calibration_ewma_alpha <= 0.0
            || self.comm_calibration_ewma_alpha > 1.0
        {
            return Err(Error::Config(format!(
                "comm_calibration_ewma_alpha must be in (0, 1], got {}",
                self.comm_calibration_ewma_alpha
            )));
        }
        if let Some(e) = &self.engine {
            if e.variant != "pallas" && e.variant != "ref" {
                return Err(Error::Config(format!(
                    "engine.variant must be \"pallas\" or \"ref\", got {:?}",
                    e.variant
                )));
            }
        }
        Ok(())
    }

    /// Total worker capacity.
    pub fn max_workers(&self) -> usize {
        self.schedulers * self.workers_per_scheduler
    }

    /// The communication α/β [`CostModel`] this config describes.
    pub fn comm_cost_model(&self) -> CostModel {
        self.comm_cost_model.clone().into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        TopologyConfig::default().validate().unwrap();
    }

    #[test]
    fn execution_mode_parses_and_roundtrips() {
        assert_eq!(TopologyConfig::default().execution_mode, ExecutionMode::Dataflow);
        let cfg =
            TopologyConfig::from_json_text(r#"{"execution_mode": "barrier"}"#).unwrap();
        assert_eq!(cfg.execution_mode, ExecutionMode::Barrier);
        let back = TopologyConfig::from_json_text(&cfg.to_json()).unwrap();
        assert_eq!(back.execution_mode, ExecutionMode::Barrier);
        assert!(TopologyConfig::from_json_text(r#"{"execution_mode": "bsp"}"#).is_err());
        assert!(TopologyConfig::from_json_text(r#"{"execution_mode": 3}"#).is_err());
    }

    #[test]
    fn memory_budget_knobs_parse_and_roundtrip() {
        let dflt = TopologyConfig::default();
        assert_eq!(dflt.memory_budget_bytes, 0);
        assert_eq!(dflt.spill_dir, None);
        assert_eq!(dflt.eviction_policy, EvictionPolicy::CostAwareLru);
        let cfg = TopologyConfig::from_json_text(
            r#"{"memory_budget_bytes": 65536, "spill_dir": "/tmp/hypar_spill",
                "eviction_policy": "lru"}"#,
        )
        .unwrap();
        assert_eq!(cfg.memory_budget_bytes, 65536);
        assert_eq!(cfg.spill_dir.as_deref(), Some(Path::new("/tmp/hypar_spill")));
        assert_eq!(cfg.eviction_policy, EvictionPolicy::Lru);
        cfg.validate().unwrap();
        let back = TopologyConfig::from_json_text(&cfg.to_json()).unwrap();
        assert_eq!(back.memory_budget_bytes, 65536);
        assert_eq!(back.spill_dir, cfg.spill_dir);
        assert_eq!(back.eviction_policy, EvictionPolicy::Lru);
    }

    #[test]
    fn bad_memory_budget_knobs_rejected() {
        assert!(
            TopologyConfig::from_json_text(r#"{"memory_budget_bytes": "big"}"#).is_err()
        );
        assert!(TopologyConfig::from_json_text(r#"{"spill_dir": 7}"#).is_err());
        assert!(TopologyConfig::from_json_text(r#"{"eviction_policy": "fifo"}"#).is_err());
        assert!(TopologyConfig::from_json_text(r#"{"eviction_policy": 1}"#).is_err());
        // JSON null is the documented "unset" spelling for spill_dir.
        let cfg = TopologyConfig::from_json_text(r#"{"spill_dir": null}"#).unwrap();
        assert_eq!(cfg.spill_dir, None);
    }

    #[test]
    fn transport_parses_and_roundtrips() {
        assert_eq!(TopologyConfig::default().transport, TransportKind::Inproc);
        let cfg = TopologyConfig::from_json_text(r#"{"transport": "tcp"}"#).unwrap();
        assert_eq!(cfg.transport, TransportKind::Tcp);
        let back = TopologyConfig::from_json_text(&cfg.to_json()).unwrap();
        assert_eq!(back.transport, TransportKind::Tcp);
        assert!(TopologyConfig::from_json_text(r#"{"transport": "infiniband"}"#).is_err());
        assert!(TopologyConfig::from_json_text(r#"{"transport": 3}"#).is_err());
    }

    #[test]
    fn speculative_prefetch_parses_and_roundtrips() {
        assert!(TopologyConfig::default().speculative_prefetch, "on by default");
        let cfg = TopologyConfig::from_json_text(r#"{"speculative_prefetch": false}"#)
            .unwrap();
        assert!(!cfg.speculative_prefetch);
        let back = TopologyConfig::from_json_text(&cfg.to_json()).unwrap();
        assert!(!back.speculative_prefetch);
        assert!(
            TopologyConfig::from_json_text(r#"{"speculative_prefetch": "yes"}"#).is_err()
        );
    }

    #[test]
    fn work_stealing_parses_and_roundtrips() {
        let d = TopologyConfig::default();
        assert!(d.work_stealing, "on by default");
        assert_eq!(d.steal_granularity, 1);
        let cfg = TopologyConfig::from_json_text(
            r#"{"work_stealing": false, "steal_granularity": 3}"#,
        )
        .unwrap();
        assert!(!cfg.work_stealing);
        assert_eq!(cfg.steal_granularity, 3);
        let back = TopologyConfig::from_json_text(&cfg.to_json()).unwrap();
        assert!(!back.work_stealing);
        assert_eq!(back.steal_granularity, 3);
        assert!(TopologyConfig::from_json_text(r#"{"work_stealing": 1}"#).is_err());
        assert!(
            TopologyConfig::from_json_text(r#"{"steal_granularity": "lots"}"#).is_err()
        );
    }

    #[test]
    fn zero_steal_granularity_rejected() {
        let cfg = TopologyConfig { steal_granularity: 0, ..Default::default() };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn json_roundtrip() {
        let mut cfg = TopologyConfig::default();
        cfg.schedulers = 3;
        cfg.comm_cost_model.simulate = true;
        cfg.engine = Some(EngineConfig {
            artifact_dir: PathBuf::from("/tmp/a"),
            variant: "pallas".into(),
        });
        let text = cfg.to_json();
        let back = TopologyConfig::from_json_text(&text).unwrap();
        assert_eq!(back.schedulers, 3);
        assert!(back.comm_cost_model.simulate);
        assert_eq!(back.engine.as_ref().unwrap().variant, "pallas");
        assert_eq!(back.engine.as_ref().unwrap().artifact_dir, PathBuf::from("/tmp/a"));
    }

    #[test]
    fn cost_model_knobs_parse_and_roundtrip() {
        let d = TopologyConfig::default();
        assert!(d.cost_model, "on by default");
        assert_eq!(d.cost_ewma_alpha, crate::cost::DEFAULT_COST_EWMA_ALPHA);
        let cfg = TopologyConfig::from_json_text(
            r#"{"cost_model": false, "cost_ewma_alpha": 0.5}"#,
        )
        .unwrap();
        assert!(!cfg.cost_model);
        assert_eq!(cfg.cost_ewma_alpha, 0.5);
        let back = TopologyConfig::from_json_text(&cfg.to_json()).unwrap();
        assert!(!back.cost_model);
        assert_eq!(back.cost_ewma_alpha, 0.5);
        assert!(TopologyConfig::from_json_text(r#"{"cost_model": "yes"}"#).is_err());
        assert!(TopologyConfig::from_json_text(r#"{"cost_ewma_alpha": "big"}"#).is_err());
    }

    #[test]
    fn legacy_cost_model_object_still_configures_the_comm_model() {
        // Pre-rename configs used `cost_model` for the α/β comm section;
        // the object form must keep working, and must not disturb the
        // (boolean) scheduling knob's default.
        let cfg = TopologyConfig::from_json_text(
            r#"{"cost_model": {"alpha_us": 7.5, "simulate": true}}"#,
        )
        .unwrap();
        assert_eq!(cfg.comm_cost_model.alpha_us, 7.5);
        assert!(cfg.comm_cost_model.simulate);
        assert!(cfg.cost_model, "scheduling knob untouched by the legacy form");
        // The canonical key wins over defaults too.
        let cfg =
            TopologyConfig::from_json_text(r#"{"comm_cost_model": {"alpha_us": 3.0}}"#)
                .unwrap();
        assert_eq!(cfg.comm_cost_model.alpha_us, 3.0);
    }

    #[test]
    fn comm_aware_knobs_parse_and_roundtrip() {
        let d = TopologyConfig::default();
        assert!(d.comm_aware_placement, "on by default");
        assert!(d.comm_calibration, "on by default");
        assert_eq!(
            d.comm_calibration_ewma_alpha,
            crate::comm::costmodel::DEFAULT_CALIBRATION_EWMA_ALPHA
        );
        let cfg = TopologyConfig::from_json_text(
            r#"{"comm_aware_placement": false, "comm_calibration": false,
                "comm_calibration_ewma_alpha": 0.7}"#,
        )
        .unwrap();
        assert!(!cfg.comm_aware_placement);
        assert!(!cfg.comm_calibration);
        assert_eq!(cfg.comm_calibration_ewma_alpha, 0.7);
        let back = TopologyConfig::from_json_text(&cfg.to_json()).unwrap();
        assert!(!back.comm_aware_placement);
        assert!(!back.comm_calibration);
        assert_eq!(back.comm_calibration_ewma_alpha, 0.7);
        assert!(
            TopologyConfig::from_json_text(r#"{"comm_aware_placement": "on"}"#).is_err()
        );
        assert!(TopologyConfig::from_json_text(r#"{"comm_calibration": 1}"#).is_err());
        assert!(TopologyConfig::from_json_text(
            r#"{"comm_calibration_ewma_alpha": "fast"}"#
        )
        .is_err());
    }

    #[test]
    fn ctrl_batching_knobs_parse_and_roundtrip() {
        let d = TopologyConfig::default();
        assert!(d.ctrl_batching, "on by default");
        assert_eq!(d.ctrl_batch_max_msgs, 64);
        assert_eq!(d.ctrl_batch_max_delay_us, 200);
        let cfg = TopologyConfig::from_json_text(
            r#"{"ctrl_batching": false, "ctrl_batch_max_msgs": 16,
                "ctrl_batch_max_delay_us": 50}"#,
        )
        .unwrap();
        assert!(!cfg.ctrl_batching);
        assert_eq!(cfg.ctrl_batch_max_msgs, 16);
        assert_eq!(cfg.ctrl_batch_max_delay_us, 50);
        let back = TopologyConfig::from_json_text(&cfg.to_json()).unwrap();
        assert!(!back.ctrl_batching);
        assert_eq!(back.ctrl_batch_max_msgs, 16);
        assert_eq!(back.ctrl_batch_max_delay_us, 50);
        assert!(TopologyConfig::from_json_text(r#"{"ctrl_batching": "on"}"#).is_err());
        assert!(
            TopologyConfig::from_json_text(r#"{"ctrl_batch_max_msgs": "many"}"#).is_err()
        );
        assert!(
            TopologyConfig::from_json_text(r#"{"ctrl_batch_max_delay_us": false}"#)
                .is_err()
        );
    }

    #[test]
    fn zero_ctrl_batch_max_msgs_rejected() {
        let cfg = TopologyConfig { ctrl_batch_max_msgs: 0, ..Default::default() };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn failure_hardening_knobs_parse_and_roundtrip() {
        let d = TopologyConfig::default();
        assert!(d.heartbeats, "on by default");
        assert_eq!(d.heartbeat_interval_ms, 200);
        assert_eq!(d.heartbeat_miss_limit, 15);
        assert!(d.straggler_deadlines, "on by default");
        assert_eq!(d.straggler_factor, 16.0);
        assert_eq!(d.straggler_cold_us, 2_000_000);
        assert_eq!(d.max_rank_losses, 4);
        assert_eq!(d.job_retry_backoff_us, 250_000);
        let cfg = TopologyConfig::from_json_text(
            r#"{"heartbeats": false, "heartbeat_interval_ms": 50,
                "heartbeat_miss_limit": 3, "straggler_deadlines": false,
                "straggler_factor": 2.5, "straggler_cold_us": 100000,
                "max_rank_losses": 1, "job_retry_backoff_us": 5000}"#,
        )
        .unwrap();
        assert!(!cfg.heartbeats);
        assert_eq!(cfg.heartbeat_interval_ms, 50);
        assert_eq!(cfg.heartbeat_miss_limit, 3);
        assert!(!cfg.straggler_deadlines);
        assert_eq!(cfg.straggler_factor, 2.5);
        assert_eq!(cfg.straggler_cold_us, 100_000);
        assert_eq!(cfg.max_rank_losses, 1);
        assert_eq!(cfg.job_retry_backoff_us, 5_000);
        let back = TopologyConfig::from_json_text(&cfg.to_json()).unwrap();
        assert!(!back.heartbeats);
        assert_eq!(back.heartbeat_interval_ms, 50);
        assert_eq!(back.heartbeat_miss_limit, 3);
        assert!(!back.straggler_deadlines);
        assert_eq!(back.straggler_factor, 2.5);
        assert_eq!(back.straggler_cold_us, 100_000);
        assert_eq!(back.max_rank_losses, 1);
        assert_eq!(back.job_retry_backoff_us, 5_000);
        assert!(TopologyConfig::from_json_text(r#"{"heartbeats": "on"}"#).is_err());
        assert!(
            TopologyConfig::from_json_text(r#"{"straggler_deadlines": 1}"#).is_err()
        );
        assert!(
            TopologyConfig::from_json_text(r#"{"straggler_factor": "big"}"#).is_err()
        );
        assert!(
            TopologyConfig::from_json_text(r#"{"heartbeat_interval_ms": "slow"}"#)
                .is_err()
        );
    }

    #[test]
    fn bad_failure_hardening_knobs_rejected() {
        let cfg = TopologyConfig { heartbeat_interval_ms: 0, ..Default::default() };
        assert!(cfg.validate().is_err());
        let cfg = TopologyConfig { heartbeat_miss_limit: 0, ..Default::default() };
        assert!(cfg.validate().is_err());
        for bad in [0.5, 0.0, f64::NAN] {
            let cfg = TopologyConfig { straggler_factor: bad, ..Default::default() };
            assert!(cfg.validate().is_err(), "factor {bad} must be rejected");
        }
    }

    #[test]
    fn bad_comm_calibration_ewma_alpha_rejected() {
        for bad in [0.0, -0.5, 1.5] {
            let cfg = TopologyConfig {
                comm_calibration_ewma_alpha: bad,
                ..Default::default()
            };
            assert!(cfg.validate().is_err(), "alpha {bad} must be rejected");
        }
    }

    #[test]
    fn bad_cost_ewma_alpha_rejected() {
        for bad in [0.0, -0.5, 1.5] {
            let cfg = TopologyConfig { cost_ewma_alpha: bad, ..Default::default() };
            assert!(cfg.validate().is_err(), "alpha {bad} must be rejected");
        }
    }

    #[test]
    fn zero_schedulers_rejected() {
        let cfg = TopologyConfig { schedulers: 0, ..Default::default() };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn bad_variant_rejected() {
        let cfg = TopologyConfig {
            engine: Some(EngineConfig { artifact_dir: "x".into(), variant: "cuda".into() }),
            ..Default::default()
        };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn partial_json_uses_defaults() {
        let cfg = TopologyConfig::from_json_text(r#"{"schedulers": 5}"#).unwrap();
        assert_eq!(cfg.schedulers, 5);
        assert_eq!(
            cfg.workers_per_scheduler,
            TopologyConfig::default().workers_per_scheduler
        );
        assert!(cfg.engine.is_none());
    }

    #[test]
    fn type_errors_reported() {
        assert!(TopologyConfig::from_json_text(r#"{"schedulers": "two"}"#).is_err());
        assert!(TopologyConfig::from_json_text("not json").is_err());
    }
}
