//! Comm substrate conformance suite: many-rank stress, collective
//! composition, cost-model injection, dynamic rank churn, matched /
//! timed / drained receives, fail-fast sends.
//!
//! Every scenario is a plain function over [`TransportKind`] and the
//! `conformance_suite!` macro instantiates the whole set once per
//! backend (`inproc::*`, `tcp::*`), so the in-process channel fabric and
//! the loopback-TCP backend (DESIGN.md §15) are held to the same
//! contract by the same assertions.

use std::time::{Duration, Instant};

use hypar::comm::collectives::ReduceOp;
use hypar::comm::{CostModel, Match, Rank, Tag, TransportKind, World};
use hypar::Error;

type W = World<Vec<u8>>;

fn world(kind: TransportKind, cost: CostModel) -> W {
    W::new_with_transport(cost, kind)
}

fn ring_pass_across_many_ranks(kind: TransportKind) {
    // Token travels a 32-rank ring 3 times.
    let world = world(kind, CostModel::free());
    let comms: Vec<_> = (0..32).map(|_| world.add_rank()).collect();
    let ranks: Vec<Rank> = comms.iter().map(|c| c.rank()).collect();
    let n = ranks.len();
    let hs: Vec<_> = comms
        .into_iter()
        .enumerate()
        .map(|(i, mut comm)| {
            let ranks = ranks.clone();
            std::thread::spawn(move || {
                let next = ranks[(i + 1) % n];
                for round in 0..3u8 {
                    if i == 0 {
                        comm.send(next, Tag(1), vec![round]).unwrap();
                        let env = comm.recv().unwrap();
                        assert_eq!(env.into_user(), vec![round]);
                    } else {
                        let env = comm.recv().unwrap();
                        let v = env.into_user();
                        assert_eq!(v, vec![round]);
                        comm.send(next, Tag(1), v).unwrap();
                    }
                }
            })
        })
        .collect();
    for h in hs {
        h.join().unwrap();
    }
    assert_eq!(world.stats().msgs, 32 * 3);
}

fn interleaved_collectives_and_p2p(kind: TransportKind) {
    // Collectives must not swallow or reorder user traffic.
    let world = world(kind, CostModel::free());
    let comms: Vec<_> = (0..4).map(|_| world.add_rank()).collect();
    let ranks: Vec<Rank> = comms.iter().map(|c| c.rank()).collect();
    let hs: Vec<_> = comms
        .into_iter()
        .enumerate()
        .map(|(i, mut comm)| {
            let ranks = ranks.clone();
            std::thread::spawn(move || {
                // Everyone sends a tagged p2p message to rank 0 FIRST...
                if i != 0 {
                    comm.send(ranks[0], Tag(42), vec![i as u8]).unwrap();
                }
                // ...then immediately enters a reduce.
                let sum = comm
                    .allreduce_f64(&ranks, vec![i as f64], ReduceOp::Sum)
                    .unwrap();
                assert_eq!(sum, vec![6.0]);
                // Rank 0 picks up the p2p messages afterwards, matched.
                if i == 0 {
                    for src in &ranks[1..] {
                        let env = comm
                            .recv_match(Match { src: Some(*src), tag: Some(Tag(42)) })
                            .unwrap();
                        assert_eq!(env.into_user(), vec![src.0 as u8]);
                    }
                }
            })
        })
        .collect();
    for h in hs {
        h.join().unwrap();
    }
}

fn cost_model_injection_slows_sends(kind: TransportKind) {
    // 1 ms per message, injected: 10 sends must take >= 10 ms.  The
    // injected delay is charged in `deliver`, above the backend
    // dispatch, so both fabrics pace identically.
    let world = world(kind, CostModel::cluster(1_000.0, f64::INFINITY));
    let a = world.add_rank();
    let mut b = world.add_rank();
    let t0 = Instant::now();
    for i in 0..10u8 {
        a.send(b.rank(), Tag(0), vec![i]).unwrap();
    }
    let elapsed = t0.elapsed();
    assert!(elapsed >= Duration::from_millis(10), "{elapsed:?}");
    for _ in 0..10 {
        b.recv().unwrap();
    }
    let s = world.stats();
    assert_eq!(s.msgs, 10);
    assert!(s.modelled_comm_ns >= 10_000_000);
}

fn rank_churn_mid_traffic(kind: TransportKind) {
    // Workers joining and leaving while others communicate.
    let world = world(kind, CostModel::free());
    let stable = world.add_rank();
    let mut sink = world.add_rank();
    let sink_rank = sink.rank();

    let hs: Vec<_> = (0..8)
        .map(|i| {
            let world = world.clone();
            std::thread::spawn(move || {
                let c = world.add_rank();
                c.send(sink_rank, Tag(i), vec![i as u8]).unwrap();
                // c drops here -> rank removed
            })
        })
        .collect();
    for h in hs {
        h.join().unwrap();
    }
    let mut got = Vec::new();
    for _ in 0..8 {
        got.push(sink.recv().unwrap().into_user()[0]);
    }
    got.sort_unstable();
    assert_eq!(got, (0..8).collect::<Vec<u8>>());
    // Dead ranks are unreachable.
    assert_eq!(world.alive_count(), 2);
    let _ = stable;
}

fn heavy_concurrent_allgathers(kind: TransportKind) {
    // Repeated ring allgathers with uneven blocks under thread scheduling
    // noise — ordering guarantees must hold every round.
    let world = world(kind, CostModel::free());
    let comms: Vec<_> = (0..6).map(|_| world.add_rank()).collect();
    let ranks: Vec<Rank> = comms.iter().map(|c| c.rank()).collect();
    let sizes: Vec<usize> = (0..6).map(|i| i + 1).collect();
    let hs: Vec<_> = comms
        .into_iter()
        .enumerate()
        .map(|(i, mut comm)| {
            let ranks = ranks.clone();
            let sizes = sizes.clone();
            std::thread::spawn(move || {
                for round in 0..20 {
                    let local = vec![(i * 100 + round) as f32; sizes[i]];
                    let full = comm
                        .allgather_f32_ring(&ranks, local, &sizes)
                        .unwrap();
                    // verify layout
                    let mut off = 0;
                    for (k, sz) in sizes.iter().enumerate() {
                        for j in 0..*sz {
                            assert_eq!(full[off + j], (k * 100 + round) as f32);
                        }
                        off += sz;
                    }
                }
            })
        })
        .collect();
    for h in hs {
        h.join().unwrap();
    }
}

fn matched_recv_under_floods(kind: TransportKind) {
    // A rank floods with tag 9 while we match tag 1 from a specific peer.
    let world = world(kind, CostModel::free());
    let flooder = world.add_rank();
    let friend = world.add_rank();
    let mut me = world.add_rank();
    let me_rank = me.rank();

    let f = std::thread::spawn(move || {
        for i in 0..500u16 {
            flooder
                .send(me_rank, Tag(9), vec![(i % 251) as u8])
                .unwrap();
        }
    });
    let friend_rank = friend.rank();
    let g = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(5));
        friend.send(me_rank, Tag(1), vec![77]).unwrap();
    });
    let env = me
        .recv_match(Match { src: Some(friend_rank), tag: Some(Tag(1)) })
        .unwrap();
    assert_eq!(env.into_user(), vec![77]);
    f.join().unwrap();
    g.join().unwrap();
    // The flood is still deliverable afterwards, in order.
    let first = me.recv().unwrap();
    assert_eq!(first.tag, Tag(9));
    assert_eq!(first.into_user(), vec![0]);
}

fn timed_recv_misses_then_hits(kind: TransportKind) {
    let world = world(kind, CostModel::free());
    let a = world.add_rank();
    let mut b = world.add_rank();
    let a_rank = a.rank();
    let b_rank = b.rank();
    let filter = Match { src: Some(a_rank), tag: Some(Tag(7)) };

    // Nothing in flight: the deadline elapses and we get a clean None.
    let none = b.recv_match_timeout(filter, Duration::from_millis(30)).unwrap();
    assert!(none.is_none());

    // A delayed send lands well inside a generous window.
    let h = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(10));
        a.send(b_rank, Tag(7), vec![7]).unwrap();
    });
    let env = b
        .recv_match_timeout(filter, Duration::from_secs(10))
        .unwrap()
        .expect("message sent inside the window");
    assert_eq!(env.src, a_rank);
    assert_eq!(env.into_user(), vec![7]);
    h.join().unwrap();
}

fn drain_preserves_order_and_respects_bound(kind: TransportKind) {
    // Ten messages down one (src, dst) lane; drained in bounded batches
    // they must reassemble in send order on either backend.
    let world = world(kind, CostModel::free());
    let tx = world.add_rank();
    let mut rx = world.add_rank();
    let rx_rank = rx.rank();
    for i in 0..10u8 {
        tx.send(rx_rank, Tag(3), vec![i]).unwrap();
    }
    let mut got = Vec::new();
    while got.len() < 10 {
        let batch = rx.recv_drain(4).unwrap();
        assert!(!batch.is_empty() && batch.len() <= 4);
        got.extend(batch.into_iter().map(|e| e.into_user()[0]));
    }
    assert_eq!(got, (0..10).collect::<Vec<u8>>());
}

fn deregister_fails_fast_despite_warm_cache(kind: TransportKind) {
    // First send warms the per-endpoint send cache (and, over TCP, the
    // pooled connection); dropping the receiver must still fail the next
    // send immediately — the epoch check runs before backend dispatch.
    let world = world(kind, CostModel::free());
    let a = world.add_rank();
    let b = world.add_rank();
    let b_rank = b.rank();
    a.send(b_rank, Tag(0), vec![1]).unwrap();
    drop(b);
    match a.send(b_rank, Tag(0), vec![2]) {
        Err(Error::RankUnreachable(r)) => assert_eq!(r, b_rank),
        other => panic!("expected RankUnreachable, got {other:?}"),
    }
}

fn self_send_stays_local(kind: TransportKind) {
    // src == dst short-circuits through the mailbox on both backends
    // (real MPI self-sends never touch the NIC either, DESIGN.md §15).
    let world = world(kind, CostModel::free());
    let mut me = world.add_rank();
    let my_rank = me.rank();
    me.send(my_rank, Tag(5), vec![9]).unwrap();
    let env = me.recv().unwrap();
    assert_eq!(env.src, my_rank);
    assert_eq!(env.into_user(), vec![9]);
}

/// Instantiate every scenario above as a `#[test]` under one backend.
macro_rules! conformance_suite {
    ($backend:ident, $kind:expr) => {
        mod $backend {
            use super::*;

            #[test]
            fn ring_pass_across_many_ranks() {
                super::ring_pass_across_many_ranks($kind);
            }
            #[test]
            fn interleaved_collectives_and_p2p() {
                super::interleaved_collectives_and_p2p($kind);
            }
            #[test]
            fn cost_model_injection_slows_sends() {
                super::cost_model_injection_slows_sends($kind);
            }
            #[test]
            fn rank_churn_mid_traffic() {
                super::rank_churn_mid_traffic($kind);
            }
            #[test]
            fn heavy_concurrent_allgathers() {
                super::heavy_concurrent_allgathers($kind);
            }
            #[test]
            fn matched_recv_under_floods() {
                super::matched_recv_under_floods($kind);
            }
            #[test]
            fn timed_recv_misses_then_hits() {
                super::timed_recv_misses_then_hits($kind);
            }
            #[test]
            fn drain_preserves_order_and_respects_bound() {
                super::drain_preserves_order_and_respects_bound($kind);
            }
            #[test]
            fn deregister_fails_fast_despite_warm_cache() {
                super::deregister_fails_fast_despite_warm_cache($kind);
            }
            #[test]
            fn self_send_stays_local() {
                super::self_send_stays_local($kind);
            }
        }
    };
}

conformance_suite!(inproc, TransportKind::Inproc);
conformance_suite!(tcp, TransportKind::Tcp);

#[test]
fn bandwidth_term_scales_with_payload() {
    // Pure model arithmetic — backend-independent by construction.
    let m = CostModel { alpha_us: 0.0, bandwidth_gbps: 1.0, simulate: false };
    let d_small = m.duration(1_000);
    let d_big = m.duration(1_000_000);
    assert!(d_big >= d_small * 900);
}
