//! Comm substrate integration tests: many-rank stress, collective
//! composition, cost-model injection, dynamic rank churn.

use std::time::{Duration, Instant};

use hypar::comm::collectives::ReduceOp;
use hypar::comm::{CostModel, Match, Rank, Tag, World};

type W = World<Vec<u8>>;

#[test]
fn ring_pass_across_many_ranks() {
    // Token travels a 32-rank ring 3 times.
    let world = W::new(CostModel::free());
    let comms: Vec<_> = (0..32).map(|_| world.add_rank()).collect();
    let ranks: Vec<Rank> = comms.iter().map(|c| c.rank()).collect();
    let n = ranks.len();
    let hs: Vec<_> = comms
        .into_iter()
        .enumerate()
        .map(|(i, mut comm)| {
            let ranks = ranks.clone();
            std::thread::spawn(move || {
                let next = ranks[(i + 1) % n];
                for round in 0..3u8 {
                    if i == 0 {
                        comm.send(next, Tag(1), vec![round]).unwrap();
                        let env = comm.recv().unwrap();
                        assert_eq!(env.into_user(), vec![round]);
                    } else {
                        let env = comm.recv().unwrap();
                        let v = env.into_user();
                        assert_eq!(v, vec![round]);
                        comm.send(next, Tag(1), v).unwrap();
                    }
                }
            })
        })
        .collect();
    for h in hs {
        h.join().unwrap();
    }
    assert_eq!(world.stats().msgs, 32 * 3);
}

#[test]
fn interleaved_collectives_and_p2p() {
    // Collectives must not swallow or reorder user traffic.
    let world = W::new(CostModel::free());
    let comms: Vec<_> = (0..4).map(|_| world.add_rank()).collect();
    let ranks: Vec<Rank> = comms.iter().map(|c| c.rank()).collect();
    let hs: Vec<_> = comms
        .into_iter()
        .enumerate()
        .map(|(i, mut comm)| {
            let ranks = ranks.clone();
            std::thread::spawn(move || {
                // Everyone sends a tagged p2p message to rank 0 FIRST...
                if i != 0 {
                    comm.send(ranks[0], Tag(42), vec![i as u8]).unwrap();
                }
                // ...then immediately enters a reduce.
                let sum = comm
                    .allreduce_f64(&ranks, vec![i as f64], ReduceOp::Sum)
                    .unwrap();
                assert_eq!(sum, vec![6.0]);
                // Rank 0 picks up the p2p messages afterwards, matched.
                if i == 0 {
                    for src in &ranks[1..] {
                        let env = comm
                            .recv_match(Match { src: Some(*src), tag: Some(Tag(42)) })
                            .unwrap();
                        assert_eq!(env.into_user(), vec![src.0 as u8]);
                    }
                }
            })
        })
        .collect();
    for h in hs {
        h.join().unwrap();
    }
}

#[test]
fn cost_model_injection_slows_sends() {
    // 1 ms per message, injected: 10 sends must take >= 10 ms.
    let world = W::new(CostModel::cluster(1_000.0, f64::INFINITY));
    let a = world.add_rank();
    let mut b = world.add_rank();
    let t0 = Instant::now();
    for i in 0..10u8 {
        a.send(b.rank(), Tag(0), vec![i]).unwrap();
    }
    let elapsed = t0.elapsed();
    assert!(elapsed >= Duration::from_millis(10), "{elapsed:?}");
    for _ in 0..10 {
        b.recv().unwrap();
    }
    let s = world.stats();
    assert_eq!(s.msgs, 10);
    assert!(s.modelled_comm_ns >= 10_000_000);
}

#[test]
fn bandwidth_term_scales_with_payload() {
    let m = CostModel { alpha_us: 0.0, bandwidth_gbps: 1.0, simulate: false };
    let d_small = m.duration(1_000);
    let d_big = m.duration(1_000_000);
    assert!(d_big >= d_small * 900);
}

#[test]
fn rank_churn_mid_traffic() {
    // Workers joining and leaving while others communicate.
    let world = W::new(CostModel::free());
    let stable = world.add_rank();
    let mut sink = world.add_rank();
    let sink_rank = sink.rank();

    let hs: Vec<_> = (0..8)
        .map(|i| {
            let world = world.clone();
            std::thread::spawn(move || {
                let c = world.add_rank();
                c.send(sink_rank, Tag(i), vec![i as u8]).unwrap();
                // c drops here -> rank removed
            })
        })
        .collect();
    for h in hs {
        h.join().unwrap();
    }
    let mut got = Vec::new();
    for _ in 0..8 {
        got.push(sink.recv().unwrap().into_user()[0]);
    }
    got.sort_unstable();
    assert_eq!(got, (0..8).collect::<Vec<u8>>());
    // Dead ranks are unreachable.
    assert_eq!(world.alive_count(), 2);
    let _ = stable;
}

#[test]
fn heavy_concurrent_allgathers() {
    // Repeated ring allgathers with uneven blocks under thread scheduling
    // noise — ordering guarantees must hold every round.
    let world = W::new(CostModel::free());
    let comms: Vec<_> = (0..6).map(|_| world.add_rank()).collect();
    let ranks: Vec<Rank> = comms.iter().map(|c| c.rank()).collect();
    let sizes: Vec<usize> = (0..6).map(|i| i + 1).collect();
    let hs: Vec<_> = comms
        .into_iter()
        .enumerate()
        .map(|(i, mut comm)| {
            let ranks = ranks.clone();
            let sizes = sizes.clone();
            std::thread::spawn(move || {
                for round in 0..20 {
                    let local = vec![(i * 100 + round) as f32; sizes[i]];
                    let full = comm
                        .allgather_f32_ring(&ranks, local, &sizes)
                        .unwrap();
                    // verify layout
                    let mut off = 0;
                    for (k, sz) in sizes.iter().enumerate() {
                        for j in 0..*sz {
                            assert_eq!(full[off + j], (k * 100 + round) as f32);
                        }
                        off += sz;
                    }
                }
            })
        })
        .collect();
    for h in hs {
        h.join().unwrap();
    }
}

#[test]
fn matched_recv_under_floods() {
    // A rank floods with tag 9 while we match tag 1 from a specific peer.
    let world = W::new(CostModel::free());
    let flooder = world.add_rank();
    let friend = world.add_rank();
    let mut me = world.add_rank();
    let me_rank = me.rank();

    let f = std::thread::spawn(move || {
        for i in 0..500u16 {
            flooder
                .send(me_rank, Tag(9), vec![(i % 251) as u8])
                .unwrap();
        }
    });
    let friend_rank = friend.rank();
    let g = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(5));
        friend.send(me_rank, Tag(1), vec![77]).unwrap();
    });
    let env = me
        .recv_match(Match { src: Some(friend_rank), tag: Some(Tag(1)) })
        .unwrap();
    assert_eq!(env.into_user(), vec![77]);
    f.join().unwrap();
    g.join().unwrap();
    // The flood is still deliverable afterwards, in order.
    let first = me.recv().unwrap();
    assert_eq!(first.tag, Tag(9));
    assert_eq!(first.into_user(), vec![0]);
}
