//! Exhaustive-interleaving model checks for the two invariants e2e tests
//! cannot explore (DESIGN.md §13): the `Coalescer` flush-before-direct-send
//! FIFO contract (DESIGN.md §12) and the `SequencePool` result-slot
//! determinism under steal races (DESIGN.md §8).
//!
//! No external model-checking dependency: a plain DFS enumerates every
//! schedule of the modelled threads' atomic steps.  The coalescer suite
//! replays the *real* `Coalescer` against a real two-rank `World` for each
//! schedule; the pool suite walks a cloneable state machine that mirrors
//! `worker/pool.rs` step for step (counter-first submit, slot-indexed
//! single-writer results, in-order assembly).  Each suite also validates
//! the checker itself: a deliberately buggy mutant must be caught.
//!
//! Default bounds keep `cargo test` fast; building with
//! `RUSTFLAGS="--cfg loom"` (the dedicated CI step) deepens the
//! exploration — more model threads, more chunks, longer schedules.
#![allow(unknown_lints)]
#![allow(unexpected_cfgs)]

use std::collections::{HashSet, VecDeque};
use std::time::Duration;

use hypar::comm::{Comm, CostModel, Rank, World};
use hypar::job::JobId;
use hypar::metrics::MetricsCollector;
use hypar::scheduler::{Coalescer, CtrlBatchCfg, FwMsg, TAG_CTRL};

#[cfg(not(loom))]
const POOL_THREADS: usize = 2;
#[cfg(loom)]
const POOL_THREADS: usize = 3;

#[cfg(not(loom))]
const POOL_CHUNKS: usize = 4;
#[cfg(loom)]
const POOL_CHUNKS: usize = 6;

#[cfg(not(loom))]
const MAX_RECV_STEPS: usize = 6;
#[cfg(loom)]
const MAX_RECV_STEPS: usize = 10;

// ========================================================================
// Schedule enumeration: interleave N sender steps with receiver drains.
// `true` = the sender takes its next step, `false` = the receiver attempts
// one `try_recv`.  Trailing receiver steps beyond the last sender step are
// deterministic, so the closure finishes with its own final drain.
// ========================================================================

fn explore_schedules(sender_steps: usize, max_recv: usize, run: &mut dyn FnMut(&[bool])) {
    fn rec(
        prefix: &mut Vec<bool>,
        s_left: usize,
        r_left: usize,
        run: &mut dyn FnMut(&[bool]),
    ) {
        if s_left == 0 {
            run(prefix);
            return;
        }
        prefix.push(true);
        rec(prefix, s_left - 1, r_left, run);
        prefix.pop();
        if r_left > 0 {
            prefix.push(false);
            rec(prefix, s_left, r_left - 1, run);
            prefix.pop();
        }
    }
    rec(&mut Vec::new(), sender_steps, max_recv, run);
}

// ========================================================================
// Coalescer models: real implementation, fresh world per schedule.
// ========================================================================

struct CoalHarness {
    sender: Comm<FwMsg>,
    receiver: Comm<FwMsg>,
    coal: Coalescer,
    metrics: MetricsCollector,
    dst: Rank,
}

fn harness(max_msgs: usize) -> CoalHarness {
    let world: World<FwMsg> = World::new(CostModel::free());
    let sender = world.add_rank();
    let receiver = world.add_rank();
    let dst = receiver.rank();
    CoalHarness {
        sender,
        receiver,
        coal: Coalescer::new(CtrlBatchCfg {
            enabled: true,
            max_msgs,
            // Never trigger on wall time: schedules must be deterministic.
            max_delay: Duration::from_secs(3600),
        }),
        metrics: MetricsCollector::new(),
        dst,
    }
}

/// Marker message `k`: fixed-size, trivially distinguishable.
fn mk(k: u32) -> FwMsg {
    FwMsg::ReleaseResult { job: JobId(k) }
}

fn push_flat(msg: FwMsg, out: &mut Vec<u32>) {
    match msg {
        FwMsg::Batch(inner) => {
            for m in inner {
                push_flat(m, out);
            }
        }
        FwMsg::ReleaseResult { job } => out.push(job.0),
        other => panic!("unexpected message in model run: {other:?}"),
    }
}

fn drain_one(receiver: &mut Comm<FwMsg>, out: &mut Vec<u32>) {
    if let Ok(Some(env)) = receiver.try_recv() {
        push_flat(env.into_user(), out);
    }
}

/// Replay `steps` under every schedule; assert the receiver observes
/// exactly `expected`, in order, with every intermediate view a prefix.
fn check_fifo_all_schedules(
    expected: &[u32],
    max_msgs: usize,
    steps: &[&dyn Fn(&mut CoalHarness)],
) {
    let mut schedules = 0usize;
    explore_schedules(steps.len(), MAX_RECV_STEPS, &mut |schedule| {
        schedules += 1;
        let mut h = harness(max_msgs);
        let mut out = Vec::new();
        let mut next = 0usize;
        for &sender_turn in schedule {
            if sender_turn {
                steps[next](&mut h);
                next += 1;
            } else {
                drain_one(&mut h.receiver, &mut out);
                assert!(
                    expected.starts_with(&out),
                    "receiver observed {out:?}, not a prefix of {expected:?}"
                );
            }
        }
        // Everything is on the wire after the last step; a bounded drain
        // must produce the full expected sequence.
        for _ in 0..expected.len() + 2 {
            drain_one(&mut h.receiver, &mut out);
        }
        assert_eq!(out, expected, "schedule {schedule:?} broke FIFO");
    });
    assert!(schedules > 1, "explorer degenerated to a single schedule");
}

#[test]
fn coalescer_send_now_flushes_before_direct_send_all_schedules() {
    // Two buffered messages, then a direct send: §12 requires the flush
    // to precede the direct message on the wire in every interleaving.
    check_fifo_all_schedules(
        &[1, 2, 3],
        64,
        &[
            &|h| h.coal.send(&h.sender, &h.metrics, h.dst, mk(1)),
            &|h| h.coal.send(&h.sender, &h.metrics, h.dst, mk(2)),
            &|h| {
                h.coal
                    .send_now(&h.sender, &h.metrics, h.dst, mk(3))
                    .expect("rank alive");
            },
            &|h| h.coal.flush_all(&h.sender, &h.metrics),
        ],
    );
}

#[test]
fn coalescer_count_trigger_preserves_fifo_all_schedules() {
    // max_msgs = 2: the second buffered send auto-flushes; a later
    // buffered message then rides the pass-boundary flush after a direct
    // send already overtook the buffer — order must still hold.
    check_fifo_all_schedules(
        &[1, 2, 3, 4],
        2,
        &[
            &|h| h.coal.send(&h.sender, &h.metrics, h.dst, mk(1)),
            &|h| h.coal.send(&h.sender, &h.metrics, h.dst, mk(2)),
            &|h| h.coal.send(&h.sender, &h.metrics, h.dst, mk(3)),
            &|h| {
                h.coal
                    .send_now(&h.sender, &h.metrics, h.dst, mk(4))
                    .expect("rank alive");
            },
        ],
    );
}

#[test]
fn coalescer_group_send_preserves_fifo_all_schedules() {
    // A pre-assembled group (the multi-source CachePush frame) must also
    // flush the destination first.
    check_fifo_all_schedules(
        &[1, 2, 3],
        64,
        &[
            &|h| h.coal.send(&h.sender, &h.metrics, h.dst, mk(1)),
            &|h| {
                h.coal
                    .send_group_now(&h.sender, &h.metrics, h.dst, vec![mk(2), mk(3)])
                    .expect("rank alive");
            },
            &|h| h.coal.flush_all(&h.sender, &h.metrics),
        ],
    );
}

/// The checker checks itself: a mutant "send_now" that skips the flush
/// (direct send first, buffered messages after) must be caught as a FIFO
/// violation in every schedule.
#[test]
fn model_checker_catches_direct_send_without_flush() {
    let mut violations = 0usize;
    let mut runs = 0usize;
    explore_schedules(3, MAX_RECV_STEPS, &mut |schedule| {
        runs += 1;
        let mut h = harness(64);
        let mut out = Vec::new();
        let mut next = 0usize;
        for &sender_turn in schedule {
            if sender_turn {
                match next {
                    0 => h.coal.send(&h.sender, &h.metrics, h.dst, mk(1)),
                    1 => h.coal.send(&h.sender, &h.metrics, h.dst, mk(2)),
                    _ => {
                        // BUG under test: direct send without flush_dst.
                        h.sender.send(h.dst, TAG_CTRL, mk(3)).expect("rank alive");
                        h.coal.flush_all(&h.sender, &h.metrics);
                    }
                }
                next += 1;
            } else {
                drain_one(&mut h.receiver, &mut out);
            }
        }
        for _ in 0..5 {
            drain_one(&mut h.receiver, &mut out);
        }
        if out != [1, 2, 3] {
            violations += 1;
        }
    });
    assert_eq!(
        violations, runs,
        "every schedule must expose the missing flush (got {violations}/{runs})"
    );
}

// ========================================================================
// SequencePool model: a cloneable state machine mirroring worker/pool.rs.
//
// Mapping to the real code: `deques` are the per-sequence chunk deques
// (`PoolShared::deques`), `holding` is the task a sequence thread popped
// and is executing, the execute step is `run_task`'s chunk path — write
// the slot (`slots[i].set`, sole writer), bump `done` (AcqRel), and the
// thread observing `done == chunks` assembles in input order
// (`finish_chunk_job`).  The steal step takes the front half of the
// busiest victim's deque, runs the first stolen task and re-queues the
// rest, like `SequencePool::steal`.
// ========================================================================

#[derive(Clone, PartialEq, Eq, Hash)]
struct PoolState {
    deques: Vec<VecDeque<usize>>,
    holding: Vec<Option<usize>>,
    slots: Vec<Option<usize>>,
    writes: Vec<u8>,
    done: usize,
    assembled: usize,
    output: Vec<usize>,
}

impl PoolState {
    fn initial(threads: usize, chunks: usize) -> Self {
        let mut deques = vec![VecDeque::new(); threads];
        // The LPT deal of equal-cost chunks degenerates to round-robin.
        for c in 0..chunks {
            deques[c % threads].push_back(c);
        }
        PoolState {
            deques,
            holding: vec![None; threads],
            slots: vec![None; chunks],
            writes: vec![0; chunks],
            done: 0,
            assembled: 0,
            output: Vec::new(),
        }
    }
}

#[derive(Default)]
struct PoolStats {
    states: usize,
    terminals: usize,
    max_slot_writes: u8,
    double_assembly: bool,
    unwritten_at_assembly: bool,
    outputs: HashSet<Vec<usize>>,
}

/// One atomic step of model thread `t`, or `None` if it has nothing to do.
/// `slot_of` maps an executed chunk to the slot it writes — identity in
/// the faithful model, skewed in the mutant.
fn pool_step(s: &PoolState, t: usize, slot_of: &dyn Fn(usize) -> usize) -> Option<PoolState> {
    let chunks = s.slots.len();
    let mut n = s.clone();
    if let Some(chunk) = n.holding[t] {
        // Execute: the slot write (sole writer in the real pool) and the
        // done-counter bump are one atomic step here because the real
        // ordering (set before fetch_add(AcqRel)) makes the write visible
        // to whichever thread sees the final count.
        let slot = slot_of(chunk);
        n.writes[slot] = n.writes[slot].saturating_add(1);
        n.slots[slot] = Some(chunk);
        n.done += 1;
        n.holding[t] = None;
        if n.done == chunks {
            n.output = n.slots.iter().map(|s| s.unwrap_or(usize::MAX)).collect();
            n.assembled += 1;
        }
        return Some(n);
    }
    if let Some(chunk) = n.deques[t].pop_front() {
        n.holding[t] = Some(chunk);
        return Some(n);
    }
    // Steal: busiest victim first (the deque_est heuristic), front half.
    let victim = (0..n.deques.len())
        .filter(|&v| v != t && !n.deques[v].is_empty())
        .max_by_key(|&v| n.deques[v].len())?;
    let take = n.deques[victim].len().div_ceil(2);
    let mut grabbed = Vec::with_capacity(take);
    for _ in 0..take {
        grabbed.push(n.deques[victim].pop_front().expect("len checked"));
    }
    n.holding[t] = Some(grabbed[0]);
    for &c in &grabbed[1..] {
        n.deques[t].push_back(c);
    }
    Some(n)
}

fn explore_pool(
    state: PoolState,
    seen: &mut HashSet<PoolState>,
    stats: &mut PoolStats,
    slot_of: &dyn Fn(usize) -> usize,
) {
    if !seen.insert(state.clone()) {
        return;
    }
    stats.states += 1;
    stats.max_slot_writes = stats
        .max_slot_writes
        .max(state.writes.iter().copied().max().unwrap_or(0));
    if state.assembled > 1 {
        stats.double_assembly = true;
    }
    if state.assembled > 0 && state.output.contains(&usize::MAX) {
        stats.unwritten_at_assembly = true;
    }
    let mut any = false;
    for t in 0..state.holding.len() {
        if let Some(next) = pool_step(&state, t, slot_of) {
            any = true;
            explore_pool(next, seen, stats, slot_of);
        }
    }
    if !any {
        stats.terminals += 1;
        stats.outputs.insert(state.output.clone());
    }
}

#[test]
fn pool_result_slots_deterministic_under_all_steal_interleavings() {
    let mut seen = HashSet::new();
    let mut stats = PoolStats::default();
    explore_pool(
        PoolState::initial(POOL_THREADS, POOL_CHUNKS),
        &mut seen,
        &mut stats,
        &|chunk| chunk,
    );
    let expected: Vec<usize> = (0..POOL_CHUNKS).collect();
    assert!(stats.states > POOL_CHUNKS, "explorer degenerated");
    assert!(stats.terminals > 0, "no terminal state reached");
    assert_eq!(stats.max_slot_writes, 1, "a result slot was written twice");
    assert!(!stats.double_assembly, "assembly ran more than once");
    assert!(!stats.unwritten_at_assembly, "assembly saw an unwritten slot");
    assert_eq!(
        stats.outputs,
        HashSet::from([expected]),
        "output order must equal input order on every schedule"
    );
}

/// Checker self-test: a mutant that writes chunk `c`'s result into slot
/// `c+1` (mod chunks) fills every slot exactly once — only the in-order
/// assembly assertion can catch it, and it must.
#[test]
fn model_checker_catches_wrong_slot_writes() {
    let chunks = POOL_CHUNKS;
    let mut seen = HashSet::new();
    let mut stats = PoolStats::default();
    explore_pool(
        PoolState::initial(POOL_THREADS, chunks),
        &mut seen,
        &mut stats,
        &|chunk| (chunk + 1) % chunks,
    );
    let expected: Vec<usize> = (0..chunks).collect();
    assert!(stats.terminals > 0);
    assert!(
        !stats.outputs.contains(&expected),
        "the wrong-slot mutant must never produce the correct order"
    );
}

// ========================================================================
// Pending-counter model: `submit_chunks` increments `pending` *before*
// pushing to the deques ("counter first" in pool.rs) so a racing pop can
// never observe more queued tasks than the counter admits — the park
// predicate (`pending == 0`) would otherwise sleep through live work.
// ========================================================================

#[derive(Clone, PartialEq, Eq, Hash)]
struct CounterState {
    pending: i64,
    queued: i64,
    running: i64,
    submit_pc: Vec<u8>,
}

fn counter_violation(counter_first: bool) -> bool {
    let submitters = 2;
    let mut stack = vec![CounterState {
        pending: 0,
        queued: 0,
        running: 0,
        submit_pc: vec![0; submitters],
    }];
    let mut seen: HashSet<CounterState> = stack.iter().cloned().collect();
    let mut violated = false;
    while let Some(s) = stack.pop() {
        // The invariant the real pool relies on, checked at every state.
        if s.queued > s.pending || s.pending < 0 {
            violated = true;
            continue;
        }
        let mut push = |n: CounterState| {
            if seen.insert(n.clone()) {
                stack.push(n);
            }
        };
        for i in 0..submitters {
            let mut n = s.clone();
            match n.submit_pc[i] {
                0 => {
                    if counter_first {
                        n.pending += 1;
                    } else {
                        n.queued += 1;
                    }
                    n.submit_pc[i] = 1;
                    push(n);
                }
                1 => {
                    if counter_first {
                        n.queued += 1;
                    } else {
                        n.pending += 1;
                    }
                    n.submit_pc[i] = 2;
                    push(n);
                }
                _ => {}
            }
        }
        // The consumer: pop a queued task, or retire a running one
        // (pending is decremented only after the task completes).
        if s.queued > 0 {
            let mut n = s.clone();
            n.queued -= 1;
            n.running += 1;
            push(n);
        }
        if s.running > 0 {
            let mut n = s.clone();
            n.running -= 1;
            n.pending -= 1;
            push(n);
        }
    }
    violated
}

#[test]
fn pool_counter_first_submit_holds_on_all_schedules() {
    assert!(
        !counter_violation(true),
        "counter-first submit must keep pending >= queued everywhere"
    );
}

#[test]
fn model_checker_catches_queue_before_counter_submit() {
    assert!(
        counter_violation(false),
        "queue-before-counter must expose a transient pending < queued"
    );
}
