//! Cross-module integration: script files from disk, JSON topology
//! configs, cost-model-injected runs, solver cross-checks, metrics
//! consistency — the glue the other suites don't cover.

use std::io::Write;

use hypar::comm::CostModel;
use hypar::prelude::*;
use hypar::job::registry::demo_registry;
use hypar::solvers::{self, cg, jacobi_fw, jacobi_mpi, JacobiConfig};

#[test]
fn script_file_plus_config_file_roundtrip() {
    let dir = std::env::temp_dir().join(format!("hypar-test-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    let script_path = dir.join("pipeline.job");
    let mut f = std::fs::File::create(&script_path).unwrap();
    writeln!(f, "# demo pipeline").unwrap();
    writeln!(f, "J1(1,1,0);").unwrap();

    let cfg_path = dir.join("topo.json");
    std::fs::write(
        &cfg_path,
        r#"{"schedulers": 2, "workers_per_scheduler": 2, "cores_per_worker": 2}"#,
    )
    .unwrap();

    let cfg = TopologyConfig::from_json_file(&cfg_path).unwrap();
    assert_eq!(cfg.schedulers, 2);
    let algo = Algorithm::parse(&std::fs::read_to_string(&script_path).unwrap()).unwrap();
    let fw = Framework::builder()
        .config(cfg)
        .registry(demo_registry())
        .build()
        .unwrap();
    let report = fw.run(algo).unwrap();
    assert_eq!(report.metrics.jobs_executed, 1);

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cost_model_injection_shows_in_wall_time() {
    // Same workload with and without injected latency: the simulated
    // cluster must be measurably slower and the modelled time recorded.
    let algo = || {
        Algorithm::parse(
            "J1(1,1,0), J2(1,1,0), J3(1,1,0), J4(1,1,0);
             J5(3,1,R1 R2 R3 R4);",
        )
        .unwrap()
    };
    let mk = |cost: CostModel| {
        let mut reg = FunctionRegistry::new();
        reg.register_plain(1, "emit", |_in, out| {
            out.push(DataChunk::from_f32(vec![1.0; 50_000])); // 200 KB
            Ok(())
        });
        reg.register_plain(3, "sum", |input, out| {
            let mut acc = 0.0f32;
            for c in input.chunks() {
                acc += c.as_f32()?.iter().sum::<f32>();
            }
            out.push(DataChunk::scalar_f32(acc));
            Ok(())
        });
        Framework::builder()
            .schedulers(2)
            .workers_per_scheduler(2)
            .comm_cost_model(cost)
            .registry(reg)
            .build()
            .unwrap()
    };
    let fast = mk(CostModel::free()).run(algo()).unwrap();
    // 200 KB at 0.1 GB/s = 2 ms per result hop; several hops per job.
    let slow = mk(CostModel::cluster(100.0, 0.1)).run(algo()).unwrap();
    assert_eq!(
        fast.result(5).unwrap().chunk(0).unwrap().first_f32().unwrap(),
        200_000.0
    );
    assert_eq!(
        slow.result(5).unwrap().chunk(0).unwrap().first_f32().unwrap(),
        200_000.0
    );
    assert!(slow.metrics.modelled_comm_us > 4_000);
    assert!(
        slow.metrics.wall_time_us > fast.metrics.wall_time_us,
        "injection had no effect: {} vs {}",
        slow.metrics.wall_time_us,
        fast.metrics.wall_time_us
    );
}

#[test]
fn fw_and_mpi_jacobi_agree_bitwise_rust_path() {
    // The central Figure-3 precondition: both sides compute the same
    // trajectory, so runtime differences are pure coordination cost.
    for procs in [1usize, 2, 4] {
        let cfg = JacobiConfig::new(128, procs, 15);
        let (fw_out, _) =
            jacobi_fw::run(&cfg, &jacobi_fw::FwTopology::default()).unwrap();
        let mpi_out = jacobi_mpi::run(&cfg).unwrap();
        assert_eq!(fw_out.x, mpi_out.x, "p={procs}");
        assert_eq!(fw_out.res_norm, mpi_out.res_norm, "p={procs}");
    }
}

#[test]
fn metrics_are_internally_consistent() {
    let cfg = JacobiConfig::new(96, 2, 10);
    let (_, m) = jacobi_fw::run(&cfg, &jacobi_fw::FwTopology::default()).unwrap();
    // jobs: 2 (params,x0) + 2 D + 10 iterations x (2 sweeps + 1 assemble)
    assert_eq!(m.jobs_executed, 2 + 2 + 10 * 3);
    assert_eq!(m.jobs_injected, 9 * 3);
    assert!(m.workers_spawned >= 2);
    assert!(m.comm_msgs > 0);
    assert!(m.wall_time_us > 0);
    // every segment closed after opening
    for s in &m.segments {
        assert!(s.closed_us >= s.opened_us);
    }
    // per-job lifecycle ordering
    for j in m.jobs.values() {
        assert!(j.started_us >= j.assigned_us);
        assert!(j.finished_us >= j.started_us);
    }
    assert!(m.total_exec_time().as_micros() > 0);
    let _ = m.mean_dispatch_latency();
    let _ = m.scheduling_overhead();
}

#[test]
fn cg_beats_jacobi_on_iterations() {
    // Extension sanity: CG converges far faster on the same (symmetrised)
    // system family.
    let cfg = JacobiConfig::new(96, 2, 400);
    let jac = solvers::jacobi_seq(&JacobiConfig::new(96, 1, 400));
    let cgr = cg::run(&cfg, 1e-6).unwrap();
    assert!(cgr.iters * 3 < 400, "cg took {} iters", cgr.iters);
    assert!(cgr.res_norm < 1e-4);
    let _ = jac;
}

#[test]
fn demo_registry_runs_paper_like_script() {
    // A multi-segment script shaped like the paper's §3.3 sample, adapted
    // to the demo registry's functions (1=identity, 2=square, 3=sum,
    // 4=max, 5=noop): emitters first, then slicing consumers, then a
    // global reduction.
    let mut reg = demo_registry();
    // an emitter that yields 10 chunks
    reg.register_plain(7, "emit10", |_in, out| {
        for i in 0..10 {
            out.push(DataChunk::from_f32(vec![i as f32, (i * i) as f32]));
        }
        Ok(())
    });
    let script = "
        J1(7,0,0), J2(7,1,0);
        J3(2,2,R1[0..5],true), J4(2,2,R1[5..10],true), J5(3,0,R1 R2),
         J6(4,0,R1 R2);
        J7(3,1, R3 R4 R5 R6);
    ";
    let fw = Framework::builder()
        .schedulers(2)
        .workers_per_scheduler(3)
        .cores_per_worker(4)
        .registry(reg)
        .build()
        .unwrap();
    let report = fw.run(Algorithm::parse(script).unwrap()).unwrap();
    assert_eq!(report.metrics.jobs_executed, 7);
    let final_sum = report.result(7).unwrap().chunk(0).unwrap().first_f32().unwrap();
    assert!(final_sum.is_finite());
    // keep-results jobs J3/J4 must not have shipped data back
    assert!(report.results.contains_key(&JobId(7)));
}

#[test]
fn report_result_accessor() {
    let fw = Framework::builder()
        .schedulers(1)
        .workers_per_scheduler(1)
        .registry(demo_registry())
        .build()
        .unwrap();
    let report = fw.run(Algorithm::parse("J9(5,1,0);").unwrap()).unwrap();
    assert!(report.result(9).is_some());
    assert!(report.result(1).is_none());
}

#[test]
fn config_dump_parses_back() {
    let dumped = TopologyConfig::default().to_json();
    let back = TopologyConfig::from_json_text(&dumped).unwrap();
    assert_eq!(back.schedulers, TopologyConfig::default().schedulers);
    back.validate().unwrap();
}

#[test]
fn cross_scheduler_kept_fetch_via_pull() {
    // J1 and J2 both keep results, landing on different schedulers
    // (least-loaded placement); J3 consumes both -> pinned to J1's worker,
    // while J2's data must travel: FetchResult -> PullKept -> KeptData ->
    // ResultData across schedulers.
    let mut reg = FunctionRegistry::new();
    reg.register_plain(1, "seven", |_in, out| {
        out.push(DataChunk::from_f32(vec![7.0; 1000]));
        Ok(())
    });
    reg.register_plain(2, "eleven", |_in, out| {
        out.push(DataChunk::from_f32(vec![11.0; 1000]));
        Ok(())
    });
    reg.register_plain(3, "sum_both", |input, out| {
        let a: f32 = input.chunk(0)?.as_f32()?.iter().sum();
        let b: f32 = input.chunk(1)?.as_f32()?.iter().sum();
        out.push(DataChunk::scalar_f32(a + b));
        Ok(())
    });
    let fw = Framework::builder()
        .schedulers(2)
        .workers_per_scheduler(2)
        .registry(reg)
        .build()
        .unwrap();
    let report = fw
        .run(Algorithm::parse("J1(1,1,0,true), J2(2,1,0,true); J3(3,1,R1 R2);").unwrap())
        .unwrap();
    assert_eq!(
        report.result(3).unwrap().chunk(0).unwrap().first_f32().unwrap(),
        7000.0 + 11000.0
    );
}

#[test]
fn engineless_worker_rejects_engine_functions() {
    let mut reg = FunctionRegistry::new();
    reg.register_with_ctx(1, "wants_engine", |_in, _out, ctx| {
        ctx.engine()?; // NoEngine -> job fails -> run fails
        Ok(())
    });
    let fw = Framework::builder()
        .schedulers(1)
        .workers_per_scheduler(1)
        .registry(reg)
        .build()
        .unwrap();
    let err = fw.run(Algorithm::parse("J1(1,1,0);").unwrap()).unwrap_err();
    match err {
        hypar::Error::JobFailed { msg, .. } => {
            assert!(msg.contains("engine"), "unexpected message: {msg}")
        }
        other => panic!("expected JobFailed, got {other}"),
    }
}

#[test]
fn deep_pipeline_many_segments() {
    // 50-segment chain J_{i+1}(R_i): stresses segment turnover, release
    // bookkeeping, placement with data affinity.
    let mut reg = FunctionRegistry::new();
    reg.register_plain(1, "start", |_in, out| {
        out.push(DataChunk::scalar_f32(1.0));
        Ok(())
    });
    reg.register_plain(2, "inc", |input, out| {
        out.push(DataChunk::scalar_f32(
            input.chunk(0)?.first_f32()? + 1.0,
        ));
        Ok(())
    });
    let mut script = String::from("J1(1,1,0);\n");
    for i in 2..=50 {
        script.push_str(&format!("J{i}(2,1,R{});\n", i - 1));
    }
    let fw = Framework::builder()
        .schedulers(2)
        .workers_per_scheduler(2)
        .registry(reg)
        .build()
        .unwrap();
    let report = fw.run(Algorithm::parse(&script).unwrap()).unwrap();
    assert_eq!(
        report.result(50).unwrap().chunk(0).unwrap().first_f32().unwrap(),
        50.0
    );
    assert_eq!(report.metrics.segments.len(), 50);
}

#[test]
fn wide_fanout_fanin() {
    // One producer, 30 parallel consumers, one reducer — placement and
    // result-serving fan-out across 3 schedulers.
    let mut reg = FunctionRegistry::new();
    reg.register_plain(1, "emit", |_in, out| {
        for c in DataChunk::from_f32((0..300).map(|i| i as f32).collect()).split(30) {
            out.push(c);
        }
        Ok(())
    });
    reg.register_per_chunk_try(2, "sum_chunk", |c| {
        Ok(DataChunk::scalar_f32(c.as_f32()?.iter().sum()))
    });
    reg.register_plain(3, "reduce", |input, out| {
        let mut acc = 0.0f32;
        for c in input.chunks() {
            acc += c.first_f32()?;
        }
        out.push(DataChunk::scalar_f32(acc));
        Ok(())
    });
    let mut mids = Vec::new();
    let mut script = String::from("J1(1,1,0);\n");
    for k in 0..30usize {
        mids.push(format!("J{}(2,1,R1[{}..{}])", k + 2, k, k + 1));
    }
    script.push_str(&mids.join(", "));
    script.push_str(";\n");
    let refs: Vec<String> = (0..30).map(|k| format!("R{}", k + 2)).collect();
    script.push_str(&format!("J40(3,1,{});", refs.join(" ")));
    let fw = Framework::builder()
        .schedulers(3)
        .workers_per_scheduler(3)
        .registry(reg)
        .build()
        .unwrap();
    let report = fw.run(Algorithm::parse(&script).unwrap()).unwrap();
    assert_eq!(
        report.result(40).unwrap().chunk(0).unwrap().first_f32().unwrap(),
        (0..300).sum::<i32>() as f32
    );
}

#[test]
fn timeline_and_json_for_real_run() {
    let cfg = JacobiConfig::new(96, 2, 5);
    let (_, m) = jacobi_fw::run(&cfg, &jacobi_fw::FwTopology::default()).unwrap();
    let tl = m.render_timeline(60);
    assert!(tl.contains('#'));
    let parsed = hypar::util::json::parse(&m.to_json().to_string()).unwrap();
    assert_eq!(
        parsed.get("jobs_executed").unwrap().as_usize(),
        Some(m.jobs_executed)
    );
}
