//! PJRT runtime tests: load the real AOT artifacts (HLO text produced by
//! `python/compile/aot.py`), execute them, and compare against rust
//! oracles — the full python→rust interchange, end to end.
//!
//! Requires `make artifacts` (skips gracefully when absent so `cargo test`
//! works on a fresh checkout) and the `pjrt` cargo feature (the whole
//! suite compiles away without it).

#![cfg(feature = "pjrt")]

use hypar::data::{matrix, DataChunk};
use hypar::runtime::{ComputeBackend, Engine, Manifest};
use hypar::solvers::{self, heat, jacobi_fw, jacobi_mpi, JacobiConfig, KernelPath};
use hypar::util::rng::Rng;

const DIR: &str = "artifacts";

fn artifacts_available() -> bool {
    std::path::Path::new(DIR).join("manifest.json").exists()
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        }
    };
}

#[test]
fn engine_loads_and_caches_executables() {
    require_artifacts!();
    let engine = Engine::load(DIR).unwrap();
    assert!(engine.manifest().artifacts.len() >= 12);
    engine.warmup(&["jacobi_block_ref_n512_bm256"]).unwrap();
    assert_eq!(engine.cached_executables(), 1);
    engine.warmup(&["jacobi_block_ref_n512_bm256"]).unwrap();
    assert_eq!(engine.cached_executables(), 1); // cached, not recompiled
}

#[test]
fn jacobi_block_artifact_matches_rust_sweep() {
    require_artifacts!();
    let engine = Engine::load(DIR).unwrap();
    let (n, bm, off) = (512usize, 256usize, 256usize);
    let mut rng = Rng::new(11);
    let a: Vec<f32> = (0..bm * n).map(|_| rng.range_f32(-0.1, 0.1)).collect();
    let x: Vec<f32> = (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let b: Vec<f32> = (0..bm).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let invd: Vec<f32> = (0..bm).map(|_| 0.5 + rng.f32()).collect();

    for variant in ["ref", "pallas"] {
        let name = engine.manifest().jacobi_block(variant, n, bm).unwrap().to_string();
        let out = engine
            .execute(
                &name,
                &[
                    DataChunk::from_f32(a.clone()),
                    DataChunk::from_f32(x.clone()),
                    DataChunk::from_f32(b.clone()),
                    DataChunk::from_f32(invd.clone()),
                    DataChunk::scalar_i32(off as i32),
                ],
            )
            .unwrap();
        assert_eq!(out.len(), 2);
        let x_new = out[0].as_f32().unwrap();
        let res2 = out[1].first_f32().unwrap() as f64;

        let mut want = vec![0.0f32; bm];
        let want_res2 =
            solvers::rust_block_sweep(&a, &x, &b, &invd, off, &mut want, n);
        for (i, (g, w)) in x_new.iter().zip(&want).enumerate() {
            assert!(
                (g - w).abs() < 1e-3,
                "{variant} x[{i}]: {g} vs {w}"
            );
        }
        assert!(
            (res2 - want_res2).abs() < 1e-2 * want_res2.max(1.0),
            "{variant} res2: {res2} vs {want_res2}"
        );
    }
}

#[test]
fn pallas_and_ref_variants_agree_on_artifacts() {
    require_artifacts!();
    let engine = Engine::load(DIR).unwrap();
    let (n, bm) = (512usize, 128usize);
    let mut rng = Rng::new(5);
    let inputs = vec![
        DataChunk::from_f32((0..bm * n).map(|_| rng.range_f32(-0.1, 0.1)).collect()),
        DataChunk::from_f32((0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect()),
        DataChunk::from_f32((0..bm).map(|_| rng.range_f32(-1.0, 1.0)).collect()),
        DataChunk::from_f32((0..bm).map(|_| 0.5 + rng.f32()).collect()),
        DataChunk::scalar_i32(128),
    ];
    let name_p = engine.manifest().jacobi_block("pallas", n, bm).unwrap().to_string();
    let name_r = engine.manifest().jacobi_block("ref", n, bm).unwrap().to_string();
    let out_p = engine.execute(&name_p, &inputs).unwrap();
    let out_r = engine.execute(&name_r, &inputs).unwrap();
    let xp = out_p[0].as_f32().unwrap();
    let xr = out_r[0].as_f32().unwrap();
    for (i, (a, b)) in xp.iter().zip(xr).enumerate() {
        assert!((a - b).abs() < 1e-3, "x[{i}]: pallas {a} vs ref {b}");
    }
}

#[test]
fn heat_artifact_matches_rust_stencil() {
    require_artifacts!();
    let engine = Engine::load(DIR).unwrap();
    let (rows, w) = (34usize, 64usize);
    let mut rng = Rng::new(3);
    let u: Vec<f32> = (0..rows * w).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let alpha = 0.2f32;
    for variant in ["ref", "pallas"] {
        let name = engine.manifest().heat_strip(variant, rows, w).unwrap().to_string();
        let out = engine
            .execute(&name, &[DataChunk::from_f32(u.clone()), DataChunk::scalar_f32(alpha)])
            .unwrap();
        let got = out[0].as_f32().unwrap();
        assert_eq!(got.len(), (rows - 2) * w);
        // Oracle: interior update with Dirichlet columns.
        for i in 1..rows - 1 {
            for c in 1..w - 1 {
                let centre = u[i * w + c];
                let lap = u[(i - 1) * w + c] + u[(i + 1) * w + c] + u[i * w + c - 1]
                    + u[i * w + c + 1]
                    - 4.0 * centre;
                let want = centre + alpha * lap;
                let g = got[(i - 1) * w + c];
                assert!((g - want).abs() < 1e-4, "{variant} [{i},{c}]: {g} vs {want}");
            }
            // Dirichlet columns preserved
            assert_eq!(got[(i - 1) * w], u[i * w]);
            assert_eq!(got[(i - 1) * w + w - 1], u[i * w + w - 1]);
        }
    }
}

#[test]
fn bad_feed_shapes_are_rejected_before_pjrt() {
    require_artifacts!();
    let engine = Engine::load(DIR).unwrap();
    let name = engine.manifest().jacobi_block("ref", 512, 256).unwrap().to_string();
    // wrong arity
    assert!(engine.execute(&name, &[]).is_err());
    // wrong element count
    let bad = vec![
        DataChunk::from_f32(vec![0.0; 10]),
        DataChunk::from_f32(vec![0.0; 512]),
        DataChunk::from_f32(vec![0.0; 256]),
        DataChunk::from_f32(vec![0.0; 256]),
        DataChunk::scalar_i32(0),
    ];
    assert!(engine.execute(&name, &bad).is_err());
    // wrong dtype for the scalar
    let bad2 = vec![
        DataChunk::from_f32(vec![0.0; 256 * 512]),
        DataChunk::from_f32(vec![0.0; 512]),
        DataChunk::from_f32(vec![0.0; 256]),
        DataChunk::from_f32(vec![0.0; 256]),
        DataChunk::scalar_f32(0.0),
    ];
    assert!(engine.execute(&name, &bad2).is_err());
}

#[test]
fn framework_jacobi_on_engine_matches_rust_path_closely() {
    require_artifacts!();
    // Same system solved via PJRT (ref-lowered artifact) and via rust
    // loops: trajectories agree to accumulation-order tolerance.
    let base = JacobiConfig::new(500, 2, 15); // pads to 512
    let rust_out = {
        let (o, _) = jacobi_fw::run(&base, &jacobi_fw::FwTopology::default()).unwrap();
        o
    };
    let engine_cfg = base.clone().with_kernel(KernelPath::EngineRef).with_artifacts(DIR);
    let (engine_out, _) =
        jacobi_fw::run(&engine_cfg, &jacobi_fw::FwTopology::default()).unwrap();
    assert_eq!(engine_out.x.len(), rust_out.x.len());
    for (i, (a, b)) in engine_out.x.iter().zip(&rust_out.x).enumerate() {
        assert!((a - b).abs() < 1e-3, "x[{i}]: engine {a} vs rust {b}");
    }
}

#[test]
fn tailored_mpi_on_engine_converges() {
    require_artifacts!();
    let cfg = JacobiConfig::new(500, 4, 120)
        .with_kernel(KernelPath::EngineRef)
        .with_artifacts(DIR);
    let out = jacobi_mpi::run(&cfg).unwrap();
    assert!(out.error_vs(&cfg) < 5e-3, "err {}", out.error_vs(&cfg));
}

#[test]
fn framework_heat_on_pallas_engine_matches_sequential() {
    require_artifacts!();
    // Test-config artifact: rows=34, w=64 -> h=32, strips=1.
    let mut cfg = heat::HeatConfig::new(32, 64, 1, 5).with_kernel(KernelPath::EnginePallas);
    cfg.artifact_dir = DIR.into();
    let want = heat::heat_seq(&cfg);
    let (got, _) = heat::run(&cfg, 1).unwrap();
    for (i, (a, b)) in got.iter().zip(&want).enumerate() {
        assert!((a - b).abs() < 1e-4, "field[{i}]: {a} vs {b}");
    }
}

#[test]
fn manifest_paper_sizes_cover_figure3() {
    require_artifacts!();
    let m = Manifest::load(DIR).unwrap();
    for (paper, padded) in [(2709usize, 2816usize), (4209, 4352), (7209, 7424)] {
        assert_eq!(m.padded_size(paper), padded);
        for p in [1usize, 2, 4, 8] {
            let bm = padded / p;
            assert!(
                m.jacobi_block("ref", padded, bm).is_ok(),
                "missing jacobi_block ref n={padded} bm={bm}"
            );
        }
    }
    // padding preserves the solution (rust-side check)
    let sys = matrix::diag_dominant_system(100, 128, 7);
    assert_eq!(sys.n(), 128);
}
