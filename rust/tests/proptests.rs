//! Property-based tests over the coordinator invariants, using the
//! in-tree deterministic RNG as the case generator (seeds are printed on
//! failure, so every case is reproducible).
//!
//! The central property: **for any valid random algorithm DAG, the
//! framework's results equal a sequential reference interpreter's** —
//! routing, batching, chunk slicing, placement and keep-results must never
//! change the computed values.

use std::collections::BTreeMap;

use hypar::prelude::*;
use hypar::util::rng::Rng;

const CASES: u64 = 30;

/// One randomly generated job in the synthetic DAG.
#[derive(Debug, Clone)]
struct GenJob {
    id: u32,
    /// 1 = emit (seeded), 2 = per-chunk xform, 3 = concat+checksum
    func: u32,
    threads: u32,
    inputs: Vec<ChunkRef>,
    keep: bool,
}

/// A random valid algorithm: segment sizes, dependencies only backwards,
/// chunk ranges within the producer's known output arity.
fn gen_algorithm(rng: &mut Rng) -> (Vec<Vec<GenJob>>, BTreeMap<u32, usize>) {
    let segments = rng.int_in(1, 4);
    let mut next_id = 1u32;
    let mut out = Vec::new();
    // producer id -> number of output chunks (statically known per func)
    let mut arity: BTreeMap<u32, usize> = BTreeMap::new();
    let mut earlier: Vec<u32> = Vec::new();
    for _s in 0..segments {
        let jobs_n = rng.int_in(1, 5);
        let mut seg = Vec::new();
        for _ in 0..jobs_n {
            let id = next_id;
            next_id += 1;
            let (func, inputs, chunks_out) = if earlier.is_empty() || rng.bool() {
                // emitter: 2-6 chunks of seeded data
                let k = rng.int_in(2, 6);
                (1u32, Vec::new(), k)
            } else if rng.bool() {
                // per-chunk transform of a random slice of one producer
                let src = earlier[rng.below(earlier.len())];
                let avail = arity[&src];
                let lo = rng.below(avail);
                let hi = rng.int_in(lo + 1, avail);
                let range = if lo == 0 && hi == avail && rng.bool() {
                    ChunkRef::all(JobId(src))
                } else {
                    ChunkRef::slice(JobId(src), lo, hi)
                };
                (2u32, vec![range], hi - lo)
            } else {
                // checksum over 1-3 whole producers
                let k = rng.int_in(1, 3.min(earlier.len()));
                let mut refs = Vec::new();
                for _ in 0..k {
                    refs.push(ChunkRef::all(JobId(earlier[rng.below(earlier.len())])));
                }
                (3u32, refs, 1)
            };
            arity.insert(id, chunks_out);
            seg.push(GenJob {
                id,
                func,
                threads: rng.int_in(0, 3) as u32,
                inputs,
                keep: rng.bool(),
            });
        }
        earlier.extend(seg.iter().map(|j| j.id));
        out.push(seg);
    }
    // Final segment must not be keep-only? keep in the final segment is
    // fine (the master pulls kept results); leave as generated.
    (out, arity)
}

fn to_algorithm(gen: &[Vec<GenJob>]) -> Algorithm {
    let mut b = Algorithm::builder();
    for seg in gen {
        b = b.segment(
            seg.iter()
                .map(|j| {
                    JobSpec::new(j.id, j.func, j.threads)
                        .with_inputs(j.inputs.clone())
                        .with_keep(j.keep)
                })
                .collect(),
        );
    }
    b.build().expect("generated algorithm is valid")
}

fn registry() -> FunctionRegistry {
    let mut reg = FunctionRegistry::new();
    // Emitter: deterministic per-job content (seeded by the input-free
    // convention: the framework passes no input, so derive from a counter
    // chunk is impossible — use a fixed pattern; distinct jobs emitting the
    // same values is fine for the property).
    reg.register_plain(1, "emit", |_in, out| {
        for c in 0..4 {
            out.push(DataChunk::from_f32(
                (0..8).map(|i| (c * 8 + i) as f32 * 0.5).collect(),
            ));
        }
        Ok(())
    });
    reg.register_per_chunk_try(2, "xform", |c| {
        Ok(DataChunk::from_f32(
            c.as_f32()?.iter().map(|v| v * 2.0 + 1.0).collect(),
        ))
    });
    reg.register_plain(3, "checksum", |input, out| {
        let mut acc = 0.0f64;
        for (i, c) in input.chunks().iter().enumerate() {
            for (j, v) in c.as_f32()?.iter().enumerate() {
                acc += (*v as f64) * ((i + 1) as f64) + (j as f64) * 0.25;
            }
        }
        out.push(DataChunk::from_f32(vec![acc as f32]));
        Ok(())
    });
    reg
}

/// Sequential reference interpreter for the same job model.
fn interpret(gen: &[Vec<GenJob>]) -> BTreeMap<u32, Vec<Vec<f32>>> {
    let mut results: BTreeMap<u32, Vec<Vec<f32>>> = BTreeMap::new();
    for seg in gen {
        for j in seg {
            // assemble input
            let mut input: Vec<Vec<f32>> = Vec::new();
            for r in &j.inputs {
                let src = &results[&r.job.0];
                let range = match r.range {
                    ChunkRange::All => 0..src.len(),
                    ChunkRange::Range { lo, hi } => lo..hi,
                };
                input.extend(src[range].iter().cloned());
            }
            let output: Vec<Vec<f32>> = match j.func {
                1 => (0..4)
                    .map(|c| (0..8).map(|i| (c * 8 + i) as f32 * 0.5).collect())
                    .collect(),
                2 => input
                    .iter()
                    .map(|c| c.iter().map(|v| v * 2.0 + 1.0).collect())
                    .collect(),
                3 => {
                    let mut acc = 0.0f64;
                    for (i, c) in input.iter().enumerate() {
                        for (jx, v) in c.iter().enumerate() {
                            acc += (*v as f64) * ((i + 1) as f64) + (jx as f64) * 0.25;
                        }
                    }
                    vec![vec![acc as f32]]
                }
                _ => unreachable!(),
            };
            results.insert(j.id, output);
        }
    }
    results
}

/// Note: emitter always produces 4 chunks; fix the generator arity to 4.
fn fix_emitter_arity(gen: &mut [Vec<GenJob>], arity: &mut BTreeMap<u32, usize>) {
    for seg in gen.iter() {
        for j in seg {
            if j.func == 1 {
                arity.insert(j.id, 4);
            }
        }
    }
}

#[test]
fn prop_framework_matches_sequential_interpreter() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed);
        let (mut gen, mut arity) = gen_algorithm(&mut rng);
        fix_emitter_arity(&mut gen, &mut arity);
        // regenerate ranges that exceed the emitter's true arity
        let mut ok = true;
        for seg in &gen {
            for j in &seg.iter().collect::<Vec<_>>() {
                for r in &j.inputs {
                    if let ChunkRange::Range { hi, .. } = r.range {
                        if hi > arity[&r.job.0] {
                            ok = false;
                        }
                    }
                }
            }
        }
        if !ok {
            continue; // generator picked a stale arity; skip (rare)
        }

        let algo = to_algorithm(&gen);
        let want = interpret(&gen);

        let schedulers = (seed % 3 + 1) as usize;
        let fw = Framework::builder()
            .schedulers(schedulers)
            .workers_per_scheduler(3)
            .cores_per_worker(4)
            .registry(registry())
            .build()
            .unwrap();
        let report = fw
            .run(algo)
            .unwrap_or_else(|e| panic!("seed {seed}: run failed: {e}"));

        // every final-segment job's result matches the interpreter
        let last = gen.last().unwrap();
        for j in last {
            let got = report
                .results
                .get(&JobId(j.id))
                .unwrap_or_else(|| panic!("seed {seed}: missing result J{}", j.id));
            let expect = &want[&j.id];
            assert_eq!(
                got.len(),
                expect.len(),
                "seed {seed}: J{} chunk count",
                j.id
            );
            for (ci, (gc, wc)) in got.chunks().iter().zip(expect).enumerate() {
                assert_eq!(
                    gc.as_f32().unwrap(),
                    wc.as_slice(),
                    "seed {seed}: J{} chunk {ci}",
                    j.id
                );
            }
        }
    }
}

#[test]
fn prop_parser_roundtrips_generated_scripts() {
    for seed in 0..CASES {
        let mut rng = Rng::new(1000 + seed);
        let (gen, _arity) = gen_algorithm(&mut rng);
        let algo = to_algorithm(&gen);
        // render to script text
        let mut script = String::new();
        for (si, seg) in algo.segments.iter().enumerate() {
            if si > 0 {
                script.push_str(";\n");
            }
            let jobs: Vec<String> = seg
                .jobs
                .iter()
                .map(|j| {
                    let threads = match j.threads {
                        ThreadCount::Auto => 0,
                        ThreadCount::Exact(n) => n,
                    };
                    let chunks = if j.inputs.is_empty() {
                        "0".to_string()
                    } else {
                        j.inputs
                            .iter()
                            .map(|r| r.to_string())
                            .collect::<Vec<_>>()
                            .join(" ")
                    };
                    format!(
                        "J{}({},{},{},{})",
                        j.id.0, j.func.0, threads, chunks, j.keep
                    )
                })
                .collect();
            script.push_str(&jobs.join(", "));
        }
        script.push(';');
        let parsed = Algorithm::parse(&script)
            .unwrap_or_else(|e| panic!("seed {seed}: reparse failed: {e}\n{script}"));
        assert_eq!(parsed, algo, "seed {seed}: roundtrip mismatch\n{script}");
    }
}

#[test]
fn prop_chunk_split_concat_identity() {
    for seed in 0..200 {
        let mut rng = Rng::new(2000 + seed);
        let n = rng.int_in(1, 500);
        let parts = rng.int_in(1, 24);
        let v: Vec<f32> = (0..n).map(|_| rng.range_f32(-10.0, 10.0)).collect();
        let chunk = DataChunk::from_f32(v.clone());
        let split = chunk.split(parts);
        assert!(split.len() <= parts);
        let back = DataChunk::concat(&split).unwrap();
        assert_eq!(back.as_f32().unwrap(), v.as_slice(), "seed {seed}");
        // split sizes differ by at most 1
        let sizes: Vec<usize> = split.iter().map(|c| c.len()).collect();
        let mx = sizes.iter().max().unwrap();
        let mn = sizes.iter().min().unwrap();
        assert!(mx - mn <= 1, "seed {seed}: sizes {sizes:?}");
    }
}

/// Pool determinism: for any chunk list, thread count 1..=8 and chunk
/// count 0..=32 (below and above the thread count), the work-stealing
/// pool produces exactly the sequential fast path's output — same chunk
/// order, same values — and keeps doing so across reuses of the same
/// persistent pool.
#[test]
fn prop_pool_matches_sequential_under_stealing() {
    use hypar::job::registry::PerChunkShared;
    use hypar::worker::pool::{run_sequential, PoolConfig, SequencePool};
    use std::sync::Arc;

    let f: PerChunkShared = Arc::new(|c: &DataChunk| {
        Ok(DataChunk::from_f32(
            c.as_f32()?.iter().map(|v| v * 3.0 - 1.0).collect(),
        ))
    });
    for seed in 0..CASES {
        let mut rng = Rng::new(7000 + seed);
        let threads = rng.int_in(1, 8);
        let n_chunks = rng.below(33); // 0..=32
        let mut fd = FunctionData::new();
        for _ in 0..n_chunks {
            let len = rng.int_in(1, 16);
            fd.push(DataChunk::from_f32(
                (0..len).map(|_| rng.range_f32(-100.0, 100.0)).collect(),
            ));
        }
        let want = run_sequential(&f, &fd).unwrap();
        let pool = SequencePool::new(
            PoolConfig {
                work_stealing: true,
                steal_granularity: rng.int_in(1, 4),
                // Fixed-granularity stealing (PR 3 behaviour) — the
                // cost-model variant is pinned separately below.
                cost_model: false,
                ..PoolConfig::new(threads)
            },
            None,
        );
        for round in 0..3 {
            let got = pool.run_chunks(&f, &fd, threads).unwrap();
            assert_eq!(got.len(), want.len(), "seed {seed} round {round}");
            for (i, (a, b)) in got.chunks().iter().zip(want.chunks()).enumerate() {
                assert_eq!(
                    a.as_f32().unwrap(),
                    b.as_f32().unwrap(),
                    "seed {seed} round {round} chunk {i}"
                );
            }
        }
    }
}

/// Cost-model determinism (DESIGN.md §9): for any thread count 1..=8 and
/// any skewed per-chunk cost profile, a `cost_model = on` pool produces
/// exactly the sequential fast path's values — and exactly what a
/// `cost_model = off` pool produces — across repeated runs of the same
/// kind on one persistent pool (run 1 deals cold/round-robin, later runs
/// LPT-deal from the recorded history; the schedule changes, the values
/// must not).
#[test]
fn prop_pool_cost_model_matches_sequential_and_off() {
    use hypar::job::registry::PerChunkShared;
    use hypar::worker::pool::{run_sequential, PoolConfig, SequencePool};
    use std::sync::Arc;

    // Chunk cost is data-dependent: element 0 encodes a dwell time in
    // tens of microseconds, so generated profiles are arbitrarily skewed.
    let f: PerChunkShared = Arc::new(|c: &DataChunk| {
        let v = c.as_f32()?;
        let dwell = v.first().copied().unwrap_or(0.0) as u64 * 10;
        std::thread::sleep(std::time::Duration::from_micros(dwell));
        Ok(DataChunk::from_f32(v.iter().map(|x| x * 3.0 - 1.0).collect()))
    });
    for seed in 0..10 {
        let mut rng = Rng::new(9100 + seed);
        let threads = rng.int_in(1, 8);
        let n_chunks = rng.below(17); // 0..=16
        let mut fd = FunctionData::new();
        for _ in 0..n_chunks {
            // One-in-four chunks is heavy (up to ~2 ms), the rest light.
            let cost = if rng.below(4) == 0 { rng.int_in(50, 200) } else { rng.int_in(0, 5) };
            let len = rng.int_in(1, 8);
            let mut v = vec![cost as f32];
            v.extend((0..len).map(|_| rng.range_f32(-100.0, 100.0)));
            fd.push(DataChunk::from_f32(v));
        }
        let want = run_sequential(&f, &fd).unwrap();
        let on = SequencePool::new(
            PoolConfig { cost_ewma_alpha: 0.5, ..PoolConfig::new(threads) },
            None,
        );
        let off = SequencePool::new(
            PoolConfig { cost_model: false, ..PoolConfig::new(threads) },
            None,
        );
        for round in 0..3 {
            for (label, pool) in [("on", &on), ("off", &off)] {
                let got = pool.run_chunks(&f, &fd, threads).unwrap();
                assert_eq!(got.len(), want.len(), "seed {seed} round {round} {label}");
                for (i, (a, b)) in got.chunks().iter().zip(want.chunks()).enumerate() {
                    assert_eq!(
                        a.as_f32().unwrap(),
                        b.as_f32().unwrap(),
                        "seed {seed} round {round} {label} chunk {i}"
                    );
                }
            }
        }
    }
}

/// Wire-codec roundtrip over every dtype, random lengths and values
/// (including empty chunks and empty documents): decode(encode(x)) == x.
#[test]
fn prop_codec_roundtrips_all_dtypes() {
    use hypar::data::codec;
    use hypar::data::Dtype;

    fn assert_chunks_equal(seed: u64, i: usize, a: &DataChunk, b: &DataChunk) {
        assert_eq!(a.dtype(), b.dtype(), "seed {seed} chunk {i}");
        assert_eq!(a.len(), b.len(), "seed {seed} chunk {i}");
        match a.dtype() {
            Dtype::U8 => assert_eq!(a.as_u8().unwrap(), b.as_u8().unwrap(), "seed {seed}"),
            Dtype::I32 => {
                assert_eq!(a.as_i32().unwrap(), b.as_i32().unwrap(), "seed {seed}")
            }
            Dtype::I64 => {
                assert_eq!(a.as_i64().unwrap(), b.as_i64().unwrap(), "seed {seed}")
            }
            Dtype::F32 => {
                assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap(), "seed {seed}")
            }
            Dtype::F64 => {
                assert_eq!(a.as_f64().unwrap(), b.as_f64().unwrap(), "seed {seed}")
            }
        }
    }

    for seed in 0..CASES {
        let mut rng = Rng::new(8000 + seed);
        let mut fd = FunctionData::new();
        for _ in 0..rng.below(8) {
            let n = rng.below(300);
            let chunk = match rng.below(5) {
                0 => DataChunk::from_u8((0..n).map(|_| rng.below(256) as u8).collect()),
                1 => DataChunk::from_i32((0..n).map(|_| rng.next_u64() as i32).collect()),
                2 => DataChunk::from_i64((0..n).map(|_| rng.next_u64() as i64).collect()),
                3 => DataChunk::from_f32(
                    (0..n).map(|_| rng.range_f32(-1e9, 1e9)).collect(),
                ),
                _ => DataChunk::from_f64((0..n).map(|_| rng.f64() * 1e15).collect()),
            };
            // Randomly encode a zero-copy sub-view instead of the whole
            // buffer (views must serialise their window only).
            if chunk.len() >= 4 && rng.bool() {
                let lo = rng.below(chunk.len() / 2);
                let hi = rng.int_in(lo + 1, chunk.len());
                fd.push(chunk.slice(lo..hi).unwrap());
            } else {
                fd.push(chunk);
            }
        }
        let back = codec::decode(&codec::encode(&fd))
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert_eq!(back.len(), fd.len(), "seed {seed}");
        for (i, (a, b)) in fd.chunks().iter().zip(back.chunks()).enumerate() {
            assert_chunks_equal(seed, i, a, b);
        }
    }
}

#[test]
fn prop_worker_packing_never_oversubscribes() {
    use hypar::scheduler::placement::{choose_worker, WorkerChoice, WorkerSlot};
    for seed in 0..200 {
        let mut rng = Rng::new(3000 + seed);
        let cores = rng.int_in(1, 8);
        let mut slots = vec![WorkerSlot::new(Rank(1), cores)];
        let mut running: Vec<ThreadCount> = Vec::new();
        for step in 0..30 {
            if rng.bool() || running.is_empty() {
                let t: ThreadCount = (rng.int_in(0, 4) as u32).into();
                let spec = JobSpec::new(100 + step as u32, 1, 0);
                let spec = JobSpec { threads: t, ..spec };
                match choose_worker(&spec, None, &slots) {
                    WorkerChoice::Run(_) => {
                        slots[0].occupy(t);
                        running.push(t);
                    }
                    WorkerChoice::Spawn => { /* full — correct to refuse */ }
                    other => panic!("seed {seed}: unexpected {other:?}"),
                }
            } else {
                let idx = rng.below(running.len());
                let t = running.swap_remove(idx);
                slots[0].vacate(t);
            }
            // invariant: occupancy within budget
            let used: usize = running.iter().map(|t| t.packing_width(cores)).sum();
            assert!(used <= cores, "seed {seed}: oversubscribed {used}/{cores}");
            assert_eq!(slots[0].free_cores, cores - used, "seed {seed}");
        }
    }
}

#[test]
fn prop_json_roundtrip_random_documents() {
    use hypar::util::json::{parse, Json};
    fn gen_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth > 3 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.bool()),
            2 => Json::num((rng.int_in(0, 1_000_000) as f64) / 4.0),
            3 => {
                let len = rng.below(12);
                let s: String = (0..len)
                    .map(|_| {
                        let opts = ['a', 'Z', '0', ' ', '"', '\\', '\n', 'é', '🦀'];
                        opts[rng.below(opts.len())]
                    })
                    .collect();
                Json::str(s)
            }
            4 => Json::Arr((0..rng.below(5)).map(|_| gen_json(rng, depth + 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), gen_json(rng, depth + 1)))
                    .collect(),
            ),
        }
    }
    for seed in 0..300 {
        let mut rng = Rng::new(4000 + seed);
        let doc = gen_json(&mut rng, 0);
        for text in [doc.to_string(), doc.to_string_pretty(2)] {
            let back = parse(&text)
                .unwrap_or_else(|e| panic!("seed {seed}: {e}\n{text}"));
            assert_eq!(back, doc, "seed {seed}");
        }
    }
}

/// Control-plane batching (DESIGN.md §12) must never change computed
/// values.  For any random DAG — including runs with an injected worker
/// crash, which exercises kept-result loss and dataflow re-entry — the
/// `ctrl_batching = off` run (structurally the PR 5 control plane:
/// per-message sends, one-completion-per-receive master loop) and the
/// `ctrl_batching = on` run (coalesced frames, whole-mailbox drains, bulk
/// LPT assignment, tiny flush thresholds to force mid-pass flushes) must
/// both match the sequential reference interpreter bit-for-bit, and hence
/// each other.
#[test]
fn prop_ctrl_batching_off_is_pr5() {
    use hypar::fault::FaultInjector;
    use std::sync::Arc;

    for seed in 0..CASES {
        let mut rng = Rng::new(11_000 + seed);
        let (mut gen, mut arity) = gen_algorithm(&mut rng);
        fix_emitter_arity(&mut gen, &mut arity);
        let mut ok = true;
        for seg in &gen {
            for j in seg {
                for r in &j.inputs {
                    if let ChunkRange::Range { hi, .. } = r.range {
                        if hi > arity[&r.job.0] {
                            ok = false;
                        }
                    }
                }
            }
        }
        if !ok {
            continue; // generator picked a stale emitter arity; skip (rare)
        }

        let want = interpret(&gen);
        let schedulers = (seed % 3 + 1) as usize;
        // One case in three injects a crash on a random job: the fault
        // path (loss report, kept-result recovery, re-entry) must be
        // value-transparent under batching too.
        let crash_job: Option<u32> = if seed % 3 == 0 {
            let all: Vec<u32> =
                gen.iter().flatten().map(|j| j.id).collect();
            Some(all[rng.below(all.len())])
        } else {
            None
        };

        for batching in [false, true] {
            let fault = Arc::new(FaultInjector::none());
            if let Some(j) = crash_job {
                fault.crash_on_job(JobId(j));
            }
            let mut b = Framework::builder()
                .schedulers(schedulers)
                .workers_per_scheduler(3)
                .cores_per_worker(4)
                .ctrl_batching(batching)
                .fault_injector(fault)
                .registry(registry());
            if batching {
                // Tiny thresholds force count- and delay-trigger flushes
                // mid-pass, not just the pass-boundary flush.
                b = b.ctrl_batch_max_msgs(1 + (seed % 4) as usize)
                    .ctrl_batch_max_delay_us(if seed % 2 == 0 { 0 } else { 200 });
            }
            let report = b
                .build()
                .unwrap()
                .run(to_algorithm(&gen))
                .unwrap_or_else(|e| {
                    panic!("seed {seed} batching={batching}: run failed: {e}")
                });
            for j in gen.last().unwrap() {
                let got = report.results.get(&JobId(j.id)).unwrap_or_else(|| {
                    panic!("seed {seed} batching={batching}: missing J{}", j.id)
                });
                let expect = &want[&j.id];
                assert_eq!(
                    got.len(),
                    expect.len(),
                    "seed {seed} batching={batching}: J{} chunk count",
                    j.id
                );
                for (ci, (gc, wc)) in got.chunks().iter().zip(expect).enumerate() {
                    assert_eq!(
                        gc.as_f32().unwrap(),
                        wc.as_slice(),
                        "seed {seed} batching={batching}: J{} chunk {ci}",
                        j.id
                    );
                }
            }
        }
    }
}

/// `comm_aware_placement = off` must reproduce the PR 4 placement decision
/// **bit-for-bit** for any owner / byte / load / estimate configuration:
/// the policy entry point with no transfer model is pinned to
/// `choose_scheduler_lookahead`, the untouched pre-§10 function.
#[test]
fn prop_comm_aware_off_is_pr4_placement() {
    use std::collections::HashMap;

    use hypar::scheduler::placement::{
        choose_scheduler_lookahead, choose_scheduler_policy,
    };
    use hypar::scheduler::SourceLoc;

    for seed in 0..200u64 {
        let mut rng = Rng::new(9000 + seed);
        let n_subs = rng.int_in(1, 5);
        let subs: Vec<Rank> = (0..n_subs).map(|i| Rank(1 + i as u32)).collect();

        // A pool of producer results with random owners, sizes (spanning
        // the AFFINITY_MIN_BYTES threshold both ways) and kept flags.
        let n_results = rng.int_in(1, 8);
        let mut owners: HashMap<JobId, SourceLoc> = HashMap::new();
        let mut result_bytes: HashMap<JobId, u64> = HashMap::new();
        for i in 0..n_results {
            let id = JobId(1 + i as u32);
            let owner = subs[rng.below(subs.len())];
            let kept_on = if rng.below(4) == 0 {
                Some(Rank(100 + rng.below(4) as u32))
            } else {
                None
            };
            owners.insert(id, SourceLoc { job: id, owner, kept_on });
            if rng.bool() {
                result_bytes.insert(id, rng.int_in(0, 20_000) as u64);
            }
        }

        // The job: random subset of the results as inputs (with repeats).
        let job_id = 50u32;
        let n_inputs = rng.below(5);
        let inputs: Vec<ChunkRef> = (0..n_inputs)
            .map(|_| ChunkRef::all(JobId(1 + rng.below(n_results) as u32)))
            .collect();
        let spec = JobSpec::new(job_id, 1, rng.int_in(0, 3) as u32).with_inputs(inputs);

        // A successor referencing the job's own output plus random results.
        let succ_inputs: Vec<ChunkRef> = std::iter::once(ChunkRef::all(JobId(job_id)))
            .chain(
                (0..rng.below(3))
                    .map(|_| ChunkRef::all(JobId(1 + rng.below(n_results) as u32))),
            )
            .collect();
        let succ = JobSpec::new(51, 1, 1).with_inputs(succ_inputs);
        let successors = if rng.bool() { vec![succ] } else { Vec::new() };

        // Random queue lengths and outstanding-cost estimates.
        let mut load: HashMap<Rank, usize> = HashMap::new();
        let mut est: HashMap<Rank, u64> = HashMap::new();
        for &s in &subs {
            if rng.bool() {
                load.insert(s, rng.below(6));
            }
            if rng.bool() {
                est.insert(s, rng.int_in(0, 100_000) as u64);
            }
        }

        let pr4 = choose_scheduler_lookahead(
            &spec,
            &successors,
            &owners,
            &result_bytes,
            &load,
            &est,
            &subs,
        );
        let off = choose_scheduler_policy(
            &spec,
            &successors,
            &owners,
            &result_bytes,
            &load,
            &est,
            &subs,
            None,
        );
        assert_eq!(off, pr4, "seed {seed}: off-knob placement diverged from PR 4");
    }
}

/// With `heartbeats = off` and `straggler_deadlines = off` the control
/// plane is structurally the PR 7 loop (blocking receives, no liveness
/// bookkeeping, no speculative replicas) — for any random DAG, including
/// crash-injected runs, results must match the sequential interpreter
/// bit-for-bit.
#[test]
fn prop_failure_hardening_off_is_pr7() {
    use hypar::fault::FaultInjector;
    use std::sync::Arc;

    for seed in 0..20u64 {
        let mut rng = Rng::new(11_500 + seed);
        let (mut gen, mut arity) = gen_algorithm(&mut rng);
        fix_emitter_arity(&mut gen, &mut arity);
        let mut ok = true;
        for seg in &gen {
            for j in seg {
                for r in &j.inputs {
                    if let ChunkRange::Range { hi, .. } = r.range {
                        if hi > arity[&r.job.0] {
                            ok = false;
                        }
                    }
                }
            }
        }
        if !ok {
            continue; // generator picked a stale emitter arity; skip (rare)
        }

        let want = interpret(&gen);
        let schedulers = (seed % 3 + 1) as usize;
        let crash_job: Option<u32> = if seed % 3 == 0 {
            let all: Vec<u32> = gen.iter().flatten().map(|j| j.id).collect();
            Some(all[rng.below(all.len())])
        } else {
            None
        };

        let fault = Arc::new(FaultInjector::none());
        if let Some(j) = crash_job {
            fault.crash_on_job(JobId(j));
        }
        let report = Framework::builder()
            .schedulers(schedulers)
            .workers_per_scheduler(3)
            .cores_per_worker(4)
            .heartbeats(false)
            .straggler_deadlines(false)
            .fault_injector(fault)
            .registry(registry())
            .build()
            .unwrap()
            .run(to_algorithm(&gen))
            .unwrap_or_else(|e| panic!("seed {seed}: run failed: {e}"));
        assert_eq!(report.metrics.speculative_reexecs, 0, "seed {seed}");
        assert_eq!(report.metrics.heartbeat_misses, 0, "seed {seed}");
        for j in gen.last().unwrap() {
            let got = report
                .results
                .get(&JobId(j.id))
                .unwrap_or_else(|| panic!("seed {seed}: missing J{}", j.id));
            let expect = &want[&j.id];
            assert_eq!(got.len(), expect.len(), "seed {seed}: J{} chunk count", j.id);
            for (ci, (gc, wc)) in got.chunks().iter().zip(expect).enumerate() {
                assert_eq!(
                    gc.as_f32().unwrap(),
                    wc.as_slice(),
                    "seed {seed}: J{} chunk {ci}",
                    j.id
                );
            }
        }
    }
}

/// The §14 headline property: **seeded message chaos must be
/// value-transparent**.  For any random DAG, a run under a seeded chaos
/// plan (drops, duplicates, delays, and — one case in three — a rank
/// doomed at its n-th send) with heartbeats and straggler deadlines armed
/// must produce exactly the sequential interpreter's values.  Reordering
/// is exercised separately (unit level): the stash perturbs intra-pair
/// ordering the control protocol is entitled to rely on.
///
/// Set `HYPAR_CHAOS_SOAK=1` to widen the sweep (CI soak job).
#[test]
fn prop_chaos_matches_sequential() {
    use hypar::fault::{ChaosConfig, ChaosCrash, ChaosPlan, FaultInjector};
    use std::sync::Arc;

    let cases: u64 = if std::env::var("HYPAR_CHAOS_SOAK").is_ok() { 40 } else { 10 };
    for seed in 0..cases {
        let mut rng = Rng::new(12_000 + seed);
        let (mut gen, mut arity) = gen_algorithm(&mut rng);
        fix_emitter_arity(&mut gen, &mut arity);
        let mut ok = true;
        for seg in &gen {
            for j in seg {
                for r in &j.inputs {
                    if let ChunkRange::Range { hi, .. } = r.range {
                        if hi > arity[&r.job.0] {
                            ok = false;
                        }
                    }
                }
            }
        }
        if !ok {
            continue; // generator picked a stale emitter arity; skip (rare)
        }
        // A kept final result can die with its doomed worker *after* the
        // last consumer ran; re-materialising it during final collection
        // is PR 4's recompute path, not under test here — keep final
        // outputs on the sub-scheduler stores.
        for j in gen.last_mut().unwrap() {
            j.keep = false;
        }

        let want = interpret(&gen);
        let schedulers = 2usize;
        // Ranks: master = 0, subs = 1..=2, prespawned workers = 3..=6.
        // One case in three dooms a worker rank at a small send index.
        let crash = if seed % 3 == 0 {
            Some(ChaosCrash {
                rank: Rank(3 + rng.below(4) as u32),
                at_send: rng.int_in(1, 5),
            })
        } else {
            None
        };
        let chaos = Arc::new(ChaosPlan::new(ChaosConfig {
            seed: 0xD1CE_0000 + seed,
            drop_one_in: 6,
            drop_budget: 2,
            dup_one_in: 6,
            dup_budget: 2,
            delay_one_in: 4,
            delay_budget: 4,
            max_delay_us: 3_000,
            crash,
            ..ChaosConfig::default()
        }));
        let report = Framework::builder()
            .schedulers(schedulers)
            .workers_per_scheduler(2)
            .cores_per_worker(4)
            .prespawn_workers(true)
            .heartbeats(true)
            .heartbeat_interval_ms(25)
            .heartbeat_miss_limit(40)
            .straggler_deadlines(true)
            .straggler_factor(8.0)
            .straggler_cold_us(200_000)
            .job_retry_backoff_us(100_000)
            .max_rank_losses(2)
            .fault_injector(Arc::new(FaultInjector::none()))
            .chaos(chaos)
            .registry(registry())
            .build()
            .unwrap()
            .run(to_algorithm(&gen))
            .unwrap_or_else(|e| panic!("seed {seed}: run failed under chaos: {e}"));
        for j in gen.last().unwrap() {
            let got = report
                .results
                .get(&JobId(j.id))
                .unwrap_or_else(|| panic!("seed {seed}: missing J{}", j.id));
            let expect = &want[&j.id];
            assert_eq!(got.len(), expect.len(), "seed {seed}: J{} chunk count", j.id);
            for (ci, (gc, wc)) in got.chunks().iter().zip(expect).enumerate() {
                assert_eq!(
                    gc.as_f32().unwrap(),
                    wc.as_slice(),
                    "seed {seed}: J{} chunk {ci}",
                    j.id
                );
            }
        }
    }
}

/// Compare every final-segment result of `report` against the
/// interpreter's values, bit for bit (shared by the §16 bounded-memory
/// properties below).
fn assert_matches_interpreter(
    seed: u64,
    leg: &str,
    gen: &[Vec<GenJob>],
    want: &BTreeMap<u32, Vec<Vec<f32>>>,
    report: &hypar::framework::RunReport,
) {
    for j in gen.last().unwrap() {
        let got = report
            .results
            .get(&JobId(j.id))
            .unwrap_or_else(|| panic!("seed {seed} {leg}: missing J{}", j.id));
        let expect = &want[&j.id];
        assert_eq!(got.len(), expect.len(), "seed {seed} {leg}: J{} chunk count", j.id);
        for (ci, (gc, wc)) in got.chunks().iter().zip(expect).enumerate() {
            assert_eq!(
                gc.as_f32().unwrap(),
                wc.as_slice(),
                "seed {seed} {leg}: J{} chunk {ci}",
                j.id
            );
        }
    }
}

/// Arity-validity check shared by the §16 properties (the emitter's true
/// arity is fixed after generation; a stale sliced range is skipped).
fn gen_is_consistent(gen: &[Vec<GenJob>], arity: &BTreeMap<u32, usize>) -> bool {
    for seg in gen {
        for j in seg {
            for r in &j.inputs {
                if let ChunkRange::Range { hi, .. } = r.range {
                    if hi > arity[&r.job.0] {
                        return false;
                    }
                }
            }
        }
    }
    true
}

/// The §16 headline property: **a byte-budgeted run computes exactly the
/// unbounded run's values**.  For any random DAG, run once unbounded to
/// measure the working set (the `store_bytes` high-water metric), then
/// re-run with `memory_budget_bytes` pinned to 25–50% of it and a spill
/// directory — evictions must actually happen (`evictions > 0`), and the
/// results must match both the sequential interpreter and the unbounded
/// leg bit for bit.  The whole property repeats over the loopback-TCP
/// fabric (DESIGN.md §15), where spilled results additionally cross the
/// wire after read-back.
#[test]
fn prop_bounded_memory_matches_sequential() {
    for seed in 0..6u64 {
        let mut rng = Rng::new(53_000 + seed);
        let (mut gen, mut arity) = gen_algorithm(&mut rng);
        fix_emitter_arity(&mut gen, &mut arity);
        if !gen_is_consistent(&gen, &arity) {
            continue; // generator picked a stale emitter arity; skip (rare)
        }
        let want = interpret(&gen);
        let schedulers = (seed % 2 + 1) as usize;
        let spill_root = std::env::temp_dir()
            .join(format!("hypar_prop_mem_{}_{seed}", std::process::id()));

        for tcp in [false, true] {
            let leg = if tcp { "tcp" } else { "inproc" };
            let run = |budget: u64, spill: Option<&std::path::PathBuf>| {
                let mut b = Framework::builder()
                    .schedulers(schedulers)
                    .workers_per_scheduler(2)
                    .cores_per_worker(4)
                    .registry(registry());
                if tcp {
                    b = b.transport(TransportKind::Tcp);
                }
                if budget > 0 {
                    b = b.memory_budget_bytes(budget);
                }
                if let Some(dir) = spill {
                    b = b.spill_dir(dir.clone());
                }
                b.build()
                    .unwrap()
                    .run(to_algorithm(&gen))
                    .unwrap_or_else(|e| panic!("seed {seed} {leg}: run failed: {e}"))
            };

            // Unbounded probe: correct values + working-set measurement.
            let unbounded = run(0, None);
            assert_matches_interpreter(seed, leg, &gen, &want, &unbounded);
            assert_eq!(unbounded.metrics.evictions, 0, "seed {seed} {leg}");

            // Budget 25–50% of the measured per-store working set.
            let ws = unbounded.metrics.store_bytes;
            assert!(ws > 0, "seed {seed} {leg}: no working set measured");
            let pct = 25 + (seed % 26) as u64; // 25..=50
            let budget = (ws * pct / 100).max(1);
            let dir = spill_root.join(leg);
            let bounded = run(budget, Some(&dir));
            assert_matches_interpreter(seed, leg, &gen, &want, &bounded);
            assert!(
                bounded.metrics.evictions > 0,
                "seed {seed} {leg}: budget {budget} of {ws} B evicted nothing"
            );
            // Bit-identical to the unbounded leg, result by result.
            for j in gen.last().unwrap() {
                let a = &unbounded.results[&JobId(j.id)];
                let b = &bounded.results[&JobId(j.id)];
                assert_eq!(a.len(), b.len(), "seed {seed} {leg}: J{}", j.id);
                for (ci, (ac, bc)) in a.chunks().iter().zip(b.chunks()).enumerate() {
                    assert_eq!(
                        ac.as_f32().unwrap(),
                        bc.as_f32().unwrap(),
                        "seed {seed} {leg}: J{} chunk {ci} diverged under budget",
                        j.id
                    );
                }
            }
        }
        let _ = std::fs::remove_dir_all(&spill_root);
    }
}

/// With `memory_budget_bytes` unset the stores are structurally the PR 9
/// unbounded stores: no evictions, no spills, no eviction-driven
/// recomputes, no pin skips — and the computed values still match the
/// sequential interpreter.  Also pins the config defaults (budget 0, no
/// spill directory, cost-aware-LRU policy).
#[test]
fn prop_memory_budget_off_is_pr9() {
    let defaults = TopologyConfig::default();
    assert_eq!(defaults.memory_budget_bytes, 0, "unbounded must stay the default");
    assert!(defaults.spill_dir.is_none(), "no spill directory by default");
    assert_eq!(defaults.eviction_policy, EvictionPolicy::CostAwareLru);

    for seed in 0..10u64 {
        let mut rng = Rng::new(54_000 + seed);
        let (mut gen, mut arity) = gen_algorithm(&mut rng);
        fix_emitter_arity(&mut gen, &mut arity);
        if !gen_is_consistent(&gen, &arity) {
            continue; // generator picked a stale emitter arity; skip (rare)
        }
        let want = interpret(&gen);
        let report = Framework::builder()
            .schedulers((seed % 3 + 1) as usize)
            .workers_per_scheduler(3)
            .cores_per_worker(4)
            .registry(registry())
            .build()
            .unwrap()
            .run(to_algorithm(&gen))
            .unwrap_or_else(|e| panic!("seed {seed}: run failed: {e}"));
        assert_matches_interpreter(seed, "off", &gen, &want, &report);
        assert_eq!(report.metrics.evictions, 0, "seed {seed}");
        assert_eq!(report.metrics.spills, 0, "seed {seed}");
        assert_eq!(report.metrics.recomputes_from_eviction, 0, "seed {seed}");
        assert_eq!(report.metrics.evict_pin_skips, 0, "seed {seed}");
    }
}

/// §16 under §14 weather: a tight budget composed with a seeded chaos
/// plan (drops, duplicates, delays, a doomed worker rank every other
/// case) must still reproduce the sequential interpreter exactly — the
/// eviction/recovery interplay (a spilled result declared lost races a
/// dead worker's loss report) must converge to the same values.
///
/// Set `HYPAR_CHAOS_SOAK=1` to widen the sweep (CI soak job).
#[test]
fn prop_chaos_with_tight_budget_matches_sequential() {
    use hypar::fault::{ChaosConfig, ChaosCrash, ChaosPlan, FaultInjector};
    use std::sync::Arc;

    let cases: u64 = if std::env::var("HYPAR_CHAOS_SOAK").is_ok() { 12 } else { 4 };
    for seed in 0..cases {
        let mut rng = Rng::new(55_000 + seed);
        let (mut gen, mut arity) = gen_algorithm(&mut rng);
        fix_emitter_arity(&mut gen, &mut arity);
        if !gen_is_consistent(&gen, &arity) {
            continue; // generator picked a stale emitter arity; skip (rare)
        }
        for j in gen.last_mut().unwrap() {
            j.keep = false; // same rationale as prop_chaos_matches_sequential
        }
        let want = interpret(&gen);
        // Ranks: master = 0, subs = 1..=2, prespawned workers = 3..=6.
        let crash = if seed % 2 == 0 {
            Some(ChaosCrash {
                rank: Rank(3 + rng.below(4) as u32),
                at_send: rng.int_in(1, 5),
            })
        } else {
            None
        };
        let chaos = Arc::new(ChaosPlan::new(ChaosConfig {
            seed: 0xB0D6_0000 + seed,
            drop_one_in: 6,
            drop_budget: 2,
            dup_one_in: 6,
            dup_budget: 2,
            delay_one_in: 4,
            delay_budget: 4,
            max_delay_us: 3_000,
            crash,
            ..ChaosConfig::default()
        }));
        let dir = std::env::temp_dir()
            .join(format!("hypar_prop_chaosmem_{}_{seed}", std::process::id()));
        let report = Framework::builder()
            .schedulers(2)
            .workers_per_scheduler(2)
            .cores_per_worker(4)
            .prespawn_workers(true)
            .heartbeats(true)
            .heartbeat_interval_ms(25)
            .heartbeat_miss_limit(40)
            .straggler_deadlines(true)
            .straggler_factor(8.0)
            .straggler_cold_us(200_000)
            .job_retry_backoff_us(100_000)
            .max_rank_losses(2)
            .memory_budget_bytes(256) // far below any run's working set
            .spill_dir(dir.clone())
            .fault_injector(Arc::new(FaultInjector::none()))
            .chaos(chaos)
            .registry(registry())
            .build()
            .unwrap()
            .run(to_algorithm(&gen))
            .unwrap_or_else(|e| {
                panic!("seed {seed}: run failed under chaos with tight budget: {e}")
            });
        assert_matches_interpreter(seed, "chaos+budget", &gen, &want, &report);
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// The transport backend must be value-invisible: for random DAGs the
/// loopback-TCP fabric (DESIGN.md §15) and the in-process fabric both
/// reproduce the sequential interpreter, and each other, exactly.  Also
/// pins `transport = inproc` as the config default, so an unconfigured
/// run keeps PR 8's in-process delivery path.
#[test]
fn prop_transport_tcp_matches_inproc_and_sequential() {
    assert_eq!(
        TopologyConfig::default().transport,
        TransportKind::Inproc,
        "inproc must stay the default backend"
    );
    let env_forced = std::env::var("HYPAR_TRANSPORT").is_ok();
    for seed in 0..8u64 {
        let mut rng = Rng::new(31_000 + seed);
        let (mut gen, mut arity) = gen_algorithm(&mut rng);
        fix_emitter_arity(&mut gen, &mut arity);
        let mut ok = true;
        for seg in &gen {
            for j in seg {
                for r in &j.inputs {
                    if let ChunkRange::Range { hi, .. } = r.range {
                        if hi > arity[&r.job.0] {
                            ok = false;
                        }
                    }
                }
            }
        }
        if !ok {
            continue; // generator picked a stale emitter arity; skip (rare)
        }
        let want = interpret(&gen);

        let run = |kind: Option<TransportKind>| {
            let mut b = Framework::builder()
                .schedulers((seed % 2 + 1) as usize + 1)
                .workers_per_scheduler(2)
                .cores_per_worker(4)
                .registry(registry());
            if let Some(k) = kind {
                b = b.transport(k);
            }
            b.build()
                .unwrap()
                .run(to_algorithm(&gen))
                .unwrap_or_else(|e| panic!("seed {seed} ({kind:?}): run failed: {e}"))
        };
        let default_leg = run(None);
        let tcp_leg = run(Some(TransportKind::Tcp));
        if !env_forced {
            // `HYPAR_TRANSPORT` outranks the builder (the CI tcp job uses
            // exactly that), so the backend identity is only pinned when
            // the environment leaves the config in charge.
            assert_eq!(default_leg.metrics.transport, "inproc", "seed {seed}");
            assert_eq!(tcp_leg.metrics.transport, "tcp", "seed {seed}");
        }
        for j in gen.last().unwrap() {
            let expect = &want[&j.id];
            for (leg, report) in [("default", &default_leg), ("tcp", &tcp_leg)] {
                let got = report
                    .results
                    .get(&JobId(j.id))
                    .unwrap_or_else(|| panic!("seed {seed} {leg}: missing J{}", j.id));
                assert_eq!(
                    got.len(),
                    expect.len(),
                    "seed {seed} {leg}: J{} chunk count",
                    j.id
                );
                for (ci, (gc, wc)) in got.chunks().iter().zip(expect).enumerate() {
                    assert_eq!(
                        gc.as_f32().unwrap(),
                        wc.as_slice(),
                        "seed {seed} {leg}: J{} chunk {ci}",
                        j.id
                    );
                }
            }
        }
    }
}

/// §14's chaos property re-run over real sockets: seeded drop / delay /
/// duplicate schedules plus a doomed rank, with the envelopes travelling
/// the TCP fabric — values must still match the sequential interpreter.
/// A doomed rank's connection teardown must map onto the same rank-lost
/// recovery the in-process fabric exercises (DESIGN.md §15).
///
/// Set `HYPAR_CHAOS_SOAK=1` to widen the sweep (CI soak + tcp jobs).
#[test]
fn prop_chaos_matches_sequential_over_tcp() {
    use hypar::fault::{ChaosConfig, ChaosCrash, ChaosPlan, FaultInjector};
    use std::sync::Arc;

    let cases: u64 = if std::env::var("HYPAR_CHAOS_SOAK").is_ok() { 15 } else { 5 };
    for seed in 0..cases {
        let mut rng = Rng::new(47_000 + seed);
        let (mut gen, mut arity) = gen_algorithm(&mut rng);
        fix_emitter_arity(&mut gen, &mut arity);
        let mut ok = true;
        for seg in &gen {
            for j in seg {
                for r in &j.inputs {
                    if let ChunkRange::Range { hi, .. } = r.range {
                        if hi > arity[&r.job.0] {
                            ok = false;
                        }
                    }
                }
            }
        }
        if !ok {
            continue; // generator picked a stale emitter arity; skip (rare)
        }
        for j in gen.last_mut().unwrap() {
            j.keep = false; // same rationale as prop_chaos_matches_sequential
        }
        let want = interpret(&gen);
        // Ranks: master = 0, subs = 1..=2, prespawned workers = 3..=6.
        let crash = if seed % 2 == 0 {
            Some(ChaosCrash {
                rank: Rank(3 + rng.below(4) as u32),
                at_send: rng.int_in(1, 5),
            })
        } else {
            None
        };
        let chaos = Arc::new(ChaosPlan::new(ChaosConfig {
            seed: 0x7C90_0000 + seed,
            drop_one_in: 6,
            drop_budget: 2,
            dup_one_in: 6,
            dup_budget: 2,
            delay_one_in: 4,
            delay_budget: 4,
            max_delay_us: 3_000,
            crash,
            ..ChaosConfig::default()
        }));
        let report = Framework::builder()
            .schedulers(2)
            .workers_per_scheduler(2)
            .cores_per_worker(4)
            .prespawn_workers(true)
            .transport(TransportKind::Tcp)
            .heartbeats(true)
            .heartbeat_interval_ms(25)
            .heartbeat_miss_limit(40)
            .straggler_deadlines(true)
            .straggler_factor(8.0)
            .straggler_cold_us(200_000)
            .job_retry_backoff_us(100_000)
            .max_rank_losses(2)
            .fault_injector(Arc::new(FaultInjector::none()))
            .chaos(chaos)
            .registry(registry())
            .build()
            .unwrap()
            .run(to_algorithm(&gen))
            .unwrap_or_else(|e| panic!("seed {seed}: run failed under chaos over tcp: {e}"));
        for j in gen.last().unwrap() {
            let got = report
                .results
                .get(&JobId(j.id))
                .unwrap_or_else(|| panic!("seed {seed}: missing J{}", j.id));
            let expect = &want[&j.id];
            assert_eq!(got.len(), expect.len(), "seed {seed}: J{} chunk count", j.id);
            for (ci, (gc, wc)) in got.chunks().iter().zip(expect).enumerate() {
                assert_eq!(
                    gc.as_f32().unwrap(),
                    wc.as_slice(),
                    "seed {seed}: J{} chunk {ci}",
                    j.id
                );
            }
        }
    }
}
