//! Fault-tolerance tests: injected worker crashes, lost keep-results,
//! recompute-in-dependency-order — the paper's noted drawback ("all
//! results computed so far are lost and have to be re-computed") plus its
//! future-work item, implemented and verified.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use hypar::fault::FaultInjector;
use hypar::prelude::*;
use hypar::solvers::{self, jacobi_fw, JacobiConfig};

fn counting_registry(calls: Arc<AtomicUsize>) -> FunctionRegistry {
    let mut reg = FunctionRegistry::new();
    let c1 = calls.clone();
    reg.register_plain(1, "produce", move |_in, out| {
        c1.fetch_add(1, Ordering::SeqCst);
        out.push(DataChunk::from_f32((0..64).map(|i| i as f32).collect()));
        Ok(())
    });
    reg.register_plain(2, "consume", |input, out| {
        let s = input.chunk(0)?.as_f32()?;
        out.push(DataChunk::scalar_f32(s.iter().sum()));
        Ok(())
    });
    reg
}

#[test]
fn crash_during_execution_is_recovered() {
    // The worker executing J1 crashes; the master re-runs J1 on a fresh
    // worker and the run completes with the right answer.
    let calls = Arc::new(AtomicUsize::new(0));
    let fault = Arc::new(FaultInjector::none());
    fault.crash_on_job(JobId(1));
    let fw = Framework::builder()
        .schedulers(1)
        .workers_per_scheduler(3)
        .registry(counting_registry(calls.clone()))
        .fault_injector(fault.clone())
        .build()
        .unwrap();
    let report = fw
        .run(Algorithm::parse("J1(1,1,0); J2(2,1,R1);").unwrap())
        .unwrap();
    assert_eq!(fault.crash_count(), 1);
    assert_eq!(
        report.result(2).unwrap().chunk(0).unwrap().first_f32().unwrap(),
        (0..64).map(|i| i as f32).sum::<f32>()
    );
    assert!(report.metrics.recomputed_jobs >= 1);
}

#[test]
fn lost_kept_result_is_recomputed_before_consumer_runs() {
    // J1 keeps its result on worker W; W crashes while executing J2 (which
    // was pinned there). Recovery must re-run J1 (the kept producer), then
    // J2, and still produce the right answer.
    let calls = Arc::new(AtomicUsize::new(0));
    let fault = Arc::new(FaultInjector::none());
    fault.crash_on_job(JobId(2)); // crash whoever starts J2 first
    let fw = Framework::builder()
        .schedulers(1)
        .workers_per_scheduler(3)
        .registry(counting_registry(calls.clone()))
        .fault_injector(fault.clone())
        .build()
        .unwrap();
    let report = fw
        .run(Algorithm::parse("J1(1,1,0,true); J2(2,1,R1);").unwrap())
        .unwrap();
    assert_eq!(fault.crash_count(), 1);
    // J1 ran at least twice: original + recompute after its kept copy died
    // with the crashed worker.
    assert!(
        calls.load(Ordering::SeqCst) >= 2,
        "producer only ran {} times",
        calls.load(Ordering::SeqCst)
    );
    assert_eq!(
        report.result(2).unwrap().chunk(0).unwrap().first_f32().unwrap(),
        (0..64).map(|i| i as f32).sum::<f32>()
    );
}

#[test]
fn jacobi_survives_mid_run_worker_crash() {
    // Crash the worker executing one sweep job of a later iteration; the
    // solver must recompute the lost matrix block and still match the
    // sequential trajectory.
    let cfg = JacobiConfig::new(64, 2, 30);
    let seq = solvers::jacobi_seq(&cfg);

    let registry = jacobi_fw::build_registry(&cfg).unwrap();
    let algo = jacobi_fw::build_algorithm(&cfg).unwrap();
    let fault = Arc::new(FaultInjector::none());
    // Injected jobs allocate above max static id (900): 901.. are the
    // second iteration's sweeps; crash one of them.
    fault.crash_on_job(JobId(903));
    let fw = Framework::builder()
        .schedulers(2)
        .workers_per_scheduler(3)
        .registry(registry)
        .fault_injector(fault.clone())
        .build()
        .unwrap();
    let report = fw.run(algo).unwrap();
    assert_eq!(fault.crash_count(), 1, "crash trigger never fired");
    assert!(report.metrics.recomputed_jobs >= 1);

    let (_, data) = report.results.iter().next_back().unwrap();
    let x = data.chunk(0).unwrap().as_f32().unwrap().to_vec();
    // Identical trajectory after recovery (same deterministic arithmetic).
    assert_eq!(x, seq.x, "post-recovery trajectory diverged");
}

#[test]
fn multiple_crashes_in_one_run() {
    let fault = Arc::new(FaultInjector::none());
    fault.crash_on_job(JobId(1));
    fault.crash_on_job(JobId(3));
    let mut reg = FunctionRegistry::new();
    reg.register_plain(1, "p", |_in, out| {
        out.push(DataChunk::scalar_f32(7.0));
        Ok(())
    });
    let fw = Framework::builder()
        .schedulers(2)
        .workers_per_scheduler(2)
        .registry(reg)
        .fault_injector(fault.clone())
        .build()
        .unwrap();
    let report = fw
        .run(Algorithm::parse("J1(1,1,0), J2(1,1,0), J3(1,1,0), J4(1,1,0);").unwrap())
        .unwrap();
    assert_eq!(fault.crash_count(), 2);
    assert_eq!(report.results.len(), 4);
    for data in report.results.values() {
        assert_eq!(data.chunk(0).unwrap().first_f32().unwrap(), 7.0);
    }
}

#[test]
fn crash_by_rank_kills_specific_worker() {
    // Prespawned pool: rank-targeted crash (first worker of the sub).
    let fault = Arc::new(FaultInjector::none());
    // master = rank 0, sub = rank 1, first worker = rank 2.
    fault.crash_rank(Rank(2));
    let mut reg = FunctionRegistry::new();
    reg.register_plain(1, "p", |_in, out| {
        out.push(DataChunk::scalar_f32(1.0));
        Ok(())
    });
    let fw = Framework::builder()
        .schedulers(1)
        .workers_per_scheduler(2)
        .prespawn_workers(true)
        .registry(reg)
        .fault_injector(fault.clone())
        .build()
        .unwrap();
    let report = fw
        .run(Algorithm::parse("J1(1,1,0), J2(1,1,0);").unwrap())
        .unwrap();
    assert_eq!(fault.crash_count(), 1);
    assert_eq!(report.results.len(), 2);
}

#[test]
fn unused_lost_results_are_not_recomputed() {
    // J1's result (kept) is consumed in segment 2 and never again; even if
    // its worker later dies the master must not re-run J1. Here the worker
    // stays alive, so the producer must run exactly once end to end.
    let calls = Arc::new(AtomicUsize::new(0));
    let c1 = calls.clone();
    let mut reg = FunctionRegistry::new();
    reg.register_plain(1, "produce", move |_in, out| {
        c1.fetch_add(1, Ordering::SeqCst);
        out.push(DataChunk::scalar_f32(2.0));
        Ok(())
    });
    reg.register_plain(2, "use_then_idle", |input, out| {
        out.push(input.chunk(0)?.clone());
        Ok(())
    });
    reg.register_plain(3, "late", |_in, out| {
        std::thread::sleep(std::time::Duration::from_millis(60));
        out.push(DataChunk::scalar_f32(9.0));
        Ok(())
    });
    let fw = Framework::builder()
        .schedulers(1)
        .workers_per_scheduler(2)
        .registry(reg)
        .build()
        .unwrap();
    let report = fw
        .run(Algorithm::parse("J1(1,1,0,true); J2(2,1,R1); J3(3,1,0);").unwrap())
        .unwrap();
    assert_eq!(calls.load(Ordering::SeqCst), 1, "needless recompute");
    assert_eq!(
        report.result(3).unwrap().chunk(0).unwrap().first_f32().unwrap(),
        9.0
    );
}
