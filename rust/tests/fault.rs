//! Fault-tolerance tests: injected worker crashes, lost keep-results,
//! recompute-in-dependency-order — the paper's noted drawback ("all
//! results computed so far are lost and have to be re-computed") plus its
//! future-work item, implemented and verified.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use hypar::fault::{ChaosConfig, ChaosCrash, ChaosPlan, FaultInjector};
use hypar::prelude::*;
use hypar::solvers::{self, jacobi_fw, JacobiConfig};

fn counting_registry(calls: Arc<AtomicUsize>) -> FunctionRegistry {
    let mut reg = FunctionRegistry::new();
    let c1 = calls.clone();
    reg.register_plain(1, "produce", move |_in, out| {
        c1.fetch_add(1, Ordering::SeqCst);
        out.push(DataChunk::from_f32((0..64).map(|i| i as f32).collect()));
        Ok(())
    });
    reg.register_plain(2, "consume", |input, out| {
        let s = input.chunk(0)?.as_f32()?;
        out.push(DataChunk::scalar_f32(s.iter().sum()));
        Ok(())
    });
    reg
}

#[test]
fn crash_during_execution_is_recovered() {
    // The worker executing J1 crashes; the master re-runs J1 on a fresh
    // worker and the run completes with the right answer.
    let calls = Arc::new(AtomicUsize::new(0));
    let fault = Arc::new(FaultInjector::none());
    fault.crash_on_job(JobId(1));
    let fw = Framework::builder()
        .schedulers(1)
        .workers_per_scheduler(3)
        .registry(counting_registry(calls.clone()))
        .fault_injector(fault.clone())
        .build()
        .unwrap();
    let report = fw
        .run(Algorithm::parse("J1(1,1,0); J2(2,1,R1);").unwrap())
        .unwrap();
    assert_eq!(fault.crash_count(), 1);
    assert_eq!(
        report.result(2).unwrap().chunk(0).unwrap().first_f32().unwrap(),
        (0..64).map(|i| i as f32).sum::<f32>()
    );
    assert!(report.metrics.recomputed_jobs >= 1);
}

#[test]
fn lost_kept_result_is_recomputed_before_consumer_runs() {
    // J1 keeps its result on worker W; W crashes while executing J2 (which
    // was pinned there). Recovery must re-run J1 (the kept producer), then
    // J2, and still produce the right answer.
    let calls = Arc::new(AtomicUsize::new(0));
    let fault = Arc::new(FaultInjector::none());
    fault.crash_on_job(JobId(2)); // crash whoever starts J2 first
    let fw = Framework::builder()
        .schedulers(1)
        .workers_per_scheduler(3)
        .registry(counting_registry(calls.clone()))
        .fault_injector(fault.clone())
        .build()
        .unwrap();
    let report = fw
        .run(Algorithm::parse("J1(1,1,0,true); J2(2,1,R1);").unwrap())
        .unwrap();
    assert_eq!(fault.crash_count(), 1);
    // J1 ran at least twice: original + recompute after its kept copy died
    // with the crashed worker.
    assert!(
        calls.load(Ordering::SeqCst) >= 2,
        "producer only ran {} times",
        calls.load(Ordering::SeqCst)
    );
    assert_eq!(
        report.result(2).unwrap().chunk(0).unwrap().first_f32().unwrap(),
        (0..64).map(|i| i as f32).sum::<f32>()
    );
}

#[test]
fn jacobi_survives_mid_run_worker_crash() {
    // Crash the worker executing one sweep job of a later iteration; the
    // solver must recompute the lost matrix block and still match the
    // sequential trajectory.
    let cfg = JacobiConfig::new(64, 2, 30);
    let seq = solvers::jacobi_seq(&cfg);

    let registry = jacobi_fw::build_registry(&cfg).unwrap();
    let algo = jacobi_fw::build_algorithm(&cfg).unwrap();
    let fault = Arc::new(FaultInjector::none());
    // Injected jobs allocate above max static id (900): 901.. are the
    // second iteration's sweeps; crash one of them.
    fault.crash_on_job(JobId(903));
    let fw = Framework::builder()
        .schedulers(2)
        .workers_per_scheduler(3)
        .registry(registry)
        .fault_injector(fault.clone())
        .build()
        .unwrap();
    let report = fw.run(algo).unwrap();
    assert_eq!(fault.crash_count(), 1, "crash trigger never fired");
    assert!(report.metrics.recomputed_jobs >= 1);

    let (_, data) = report.results.iter().next_back().unwrap();
    let x = data.chunk(0).unwrap().as_f32().unwrap().to_vec();
    // Identical trajectory after recovery (same deterministic arithmetic).
    assert_eq!(x, seq.x, "post-recovery trajectory diverged");
}

#[test]
fn multiple_crashes_in_one_run() {
    let fault = Arc::new(FaultInjector::none());
    fault.crash_on_job(JobId(1));
    fault.crash_on_job(JobId(3));
    let mut reg = FunctionRegistry::new();
    reg.register_plain(1, "p", |_in, out| {
        out.push(DataChunk::scalar_f32(7.0));
        Ok(())
    });
    let fw = Framework::builder()
        .schedulers(2)
        .workers_per_scheduler(2)
        .registry(reg)
        .fault_injector(fault.clone())
        .build()
        .unwrap();
    let report = fw
        .run(Algorithm::parse("J1(1,1,0), J2(1,1,0), J3(1,1,0), J4(1,1,0);").unwrap())
        .unwrap();
    assert_eq!(fault.crash_count(), 2);
    assert_eq!(report.results.len(), 4);
    for data in report.results.values() {
        assert_eq!(data.chunk(0).unwrap().first_f32().unwrap(), 7.0);
    }
}

#[test]
fn crash_by_rank_kills_specific_worker() {
    // Prespawned pool: rank-targeted crash (first worker of the sub).
    let fault = Arc::new(FaultInjector::none());
    // master = rank 0, sub = rank 1, first worker = rank 2.
    fault.crash_rank(Rank(2));
    let mut reg = FunctionRegistry::new();
    reg.register_plain(1, "p", |_in, out| {
        out.push(DataChunk::scalar_f32(1.0));
        Ok(())
    });
    let fw = Framework::builder()
        .schedulers(1)
        .workers_per_scheduler(2)
        .prespawn_workers(true)
        .registry(reg)
        .fault_injector(fault.clone())
        .build()
        .unwrap();
    let report = fw
        .run(Algorithm::parse("J1(1,1,0), J2(1,1,0);").unwrap())
        .unwrap();
    assert_eq!(fault.crash_count(), 1);
    assert_eq!(report.results.len(), 2);
}

#[test]
fn unused_lost_results_are_not_recomputed() {
    // J1's result (kept) is consumed in segment 2 and never again; even if
    // its worker later dies the master must not re-run J1. Here the worker
    // stays alive, so the producer must run exactly once end to end.
    let calls = Arc::new(AtomicUsize::new(0));
    let c1 = calls.clone();
    let mut reg = FunctionRegistry::new();
    reg.register_plain(1, "produce", move |_in, out| {
        c1.fetch_add(1, Ordering::SeqCst);
        out.push(DataChunk::scalar_f32(2.0));
        Ok(())
    });
    reg.register_plain(2, "use_then_idle", |input, out| {
        out.push(input.chunk(0)?.clone());
        Ok(())
    });
    reg.register_plain(3, "late", |_in, out| {
        std::thread::sleep(std::time::Duration::from_millis(60));
        out.push(DataChunk::scalar_f32(9.0));
        Ok(())
    });
    let fw = Framework::builder()
        .schedulers(1)
        .workers_per_scheduler(2)
        .registry(reg)
        .build()
        .unwrap();
    let report = fw
        .run(Algorithm::parse("J1(1,1,0,true); J2(2,1,R1); J3(3,1,0);").unwrap())
        .unwrap();
    assert_eq!(calls.load(Ordering::SeqCst), 1, "needless recompute");
    assert_eq!(
        report.result(3).unwrap().chunk(0).unwrap().first_f32().unwrap(),
        9.0
    );
}

// ===== failure hardening (§14): heartbeats, stragglers, chaos ===========

#[test]
fn heartbeats_do_not_disturb_a_healthy_run() {
    // Aggressive beat interval on a healthy cluster: the run must complete
    // with no rank declared lost even though the worker sleeps well past
    // several beat periods.
    let mut reg = FunctionRegistry::new();
    reg.register_plain(1, "slow", |_in, out| {
        std::thread::sleep(std::time::Duration::from_millis(150));
        out.push(DataChunk::scalar_f32(5.0));
        Ok(())
    });
    let fw = Framework::builder()
        .schedulers(2)
        .workers_per_scheduler(2)
        .heartbeats(true)
        .heartbeat_interval_ms(10)
        .registry(reg)
        .build()
        .unwrap();
    let report = fw
        .run(Algorithm::parse("J1(1,1,0), J2(1,1,0);").unwrap())
        .unwrap();
    assert_eq!(report.metrics.ranks_lost, 0, "false-positive rank loss");
    for data in report.results.values() {
        assert_eq!(data.chunk(0).unwrap().first_f32().unwrap(), 5.0);
    }
}

#[test]
fn straggler_deadline_speculative_replica_wins() {
    // First execution of the job hangs far past its deadline; the master
    // must dispatch a speculative replica to the other sub-scheduler and
    // take the replica's (fast) completion as the winner.
    let calls = Arc::new(AtomicUsize::new(0));
    let c1 = calls.clone();
    let mut reg = FunctionRegistry::new();
    reg.register_plain(1, "sometimes_slow", move |_in, out| {
        if c1.fetch_add(1, Ordering::SeqCst) == 0 {
            std::thread::sleep(std::time::Duration::from_millis(500));
        }
        out.push(DataChunk::scalar_f32(3.0));
        Ok(())
    });
    let fw = Framework::builder()
        .schedulers(2)
        .workers_per_scheduler(1)
        .heartbeats(false)
        .straggler_deadlines(true)
        .straggler_factor(1.0)
        .straggler_cold_us(60_000)
        .job_retry_backoff_us(0)
        .registry(reg)
        .build()
        .unwrap();
    let report = fw.run(Algorithm::parse("J1(1,1,0);").unwrap()).unwrap();
    assert_eq!(
        report.result(1).unwrap().chunk(0).unwrap().first_f32().unwrap(),
        3.0
    );
    assert!(
        report.metrics.speculative_reexecs >= 1,
        "no speculative replica was dispatched"
    );
    assert!(
        report.metrics.speculative_wins >= 1,
        "replica did not win over the straggler"
    );
}

#[test]
fn chaos_drops_dups_delays_still_produce_correct_results() {
    // Seeded message-level chaos (drops, duplicates, delays — no crash):
    // straggler re-execution and duplicate-completion tolerance must absorb
    // every perturbation and the final values must be exact.
    let chaos = Arc::new(ChaosPlan::new(ChaosConfig {
        seed: 0xC0FFEE,
        drop_one_in: 5,
        drop_budget: 2,
        dup_one_in: 5,
        dup_budget: 2,
        delay_one_in: 3,
        delay_budget: 4,
        max_delay_us: 2_000,
        ..ChaosConfig::default()
    }));
    let fault = Arc::new(FaultInjector::none());
    let fw = Framework::builder()
        .schedulers(2)
        .workers_per_scheduler(2)
        .heartbeats(true)
        .heartbeat_interval_ms(25)
        .straggler_deadlines(true)
        .straggler_factor(4.0)
        .straggler_cold_us(100_000)
        .job_retry_backoff_us(50_000)
        .registry(counting_registry(Arc::new(AtomicUsize::new(0))))
        .fault_injector(fault)
        .chaos(chaos.clone())
        .build()
        .unwrap();
    let report = fw
        .run(Algorithm::parse("J1(1,1,0), J2(1,1,0); J3(2,1,R1), J4(2,1,R2);").unwrap())
        .unwrap();
    let want = (0..64).map(|i| i as f32).sum::<f32>();
    for id in [3u32, 4] {
        assert_eq!(
            report.result(id).unwrap().chunk(0).unwrap().first_f32().unwrap(),
            want,
            "J{id} value wrong under chaos"
        );
    }
    let c = chaos.counters();
    assert_eq!(report.metrics.msgs_dropped, c.dropped);
    assert_eq!(report.metrics.msgs_delayed, c.delayed);
    assert_eq!(report.metrics.msgs_duplicated, c.duplicated);
}

#[test]
fn chaos_rank_crash_recovers_within_budget() {
    // A worker rank is doomed at its first send: its completion message is
    // swallowed and the rank goes silent. The sub-scheduler's liveness scan
    // (or the straggler deadline) must recover the lost job.
    let chaos = Arc::new(ChaosPlan::new(ChaosConfig {
        seed: 42,
        // master = rank 0, sub = rank 1, prespawned workers = ranks 2..=3.
        crash: Some(ChaosCrash { rank: Rank(2), at_send: 1 }),
        ..ChaosConfig::default()
    }));
    let fault = Arc::new(FaultInjector::none());
    let mut reg = FunctionRegistry::new();
    reg.register_plain(1, "p", |_in, out| {
        out.push(DataChunk::scalar_f32(8.0));
        Ok(())
    });
    let fw = Framework::builder()
        .schedulers(1)
        .workers_per_scheduler(2)
        .prespawn_workers(true)
        .heartbeats(true)
        .heartbeat_interval_ms(25)
        .straggler_deadlines(true)
        .straggler_factor(4.0)
        .straggler_cold_us(200_000)
        .max_rank_losses(2)
        .registry(reg)
        .fault_injector(fault)
        .chaos(chaos)
        .build()
        .unwrap();
    let report = fw
        .run(Algorithm::parse("J1(1,1,0), J2(1,1,0);").unwrap())
        .unwrap();
    assert_eq!(report.results.len(), 2);
    for data in report.results.values() {
        assert_eq!(data.chunk(0).unwrap().first_f32().unwrap(), 8.0);
    }
}
