//! End-to-end framework tests: full master + sub-scheduler + worker runs
//! over the in-process comm substrate (pure-rust kernel paths — no
//! artifacts needed; the PJRT path is covered by `runtime_hlo.rs`).

use hypar::prelude::*;
use hypar::job::registry::demo_registry;
use hypar::scheduler::master::ReleasePolicy;
use hypar::solvers::{self, heat, jacobi_fw, JacobiConfig};

const BOTH_MODES: [ExecutionMode; 2] = [ExecutionMode::Barrier, ExecutionMode::Dataflow];

fn fw(schedulers: usize, workers: usize, registry: FunctionRegistry) -> Framework {
    Framework::builder()
        .schedulers(schedulers)
        .workers_per_scheduler(workers)
        .cores_per_worker(4)
        .registry(registry)
        .build()
        .unwrap()
}

#[test]
fn single_noop_job() {
    let report = fw(1, 1, demo_registry())
        .run(Algorithm::parse("J1(5,1,0);").unwrap())
        .unwrap();
    assert_eq!(report.metrics.jobs_executed, 1);
    assert!(report.results.contains_key(&JobId(1)));
    assert!(report.result(1).unwrap().is_empty()); // noop has no output
}

#[test]
fn two_segment_dataflow_square_then_sum() {
    let mut reg = FunctionRegistry::new();
    reg.register_plain(1, "emit", |_in, out| {
        out.push(DataChunk::from_f32(vec![1.0, 2.0]));
        out.push(DataChunk::from_f32(vec![3.0, 4.0]));
        out.push(DataChunk::from_f32(vec![5.0, 6.0]));
        Ok(())
    });
    reg.register_per_chunk_try(2, "square", |c| {
        Ok(DataChunk::from_f32(c.as_f32()?.iter().map(|v| v * v).collect()))
    });
    reg.register_plain(3, "sum", |input, out| {
        let mut acc = 0.0f32;
        for c in input.chunks() {
            acc += c.as_f32()?.iter().sum::<f32>();
        }
        out.push(DataChunk::scalar_f32(acc));
        Ok(())
    });

    let algo = Algorithm::parse("J1(1,1,0); J2(2,0,R1); J3(3,1,R2);").unwrap();
    let report = fw(2, 2, reg).run(algo).unwrap();
    let total = report.result(3).unwrap().chunk(0).unwrap().first_f32().unwrap();
    assert_eq!(total, (1..=6).map(|v| (v * v) as f32).sum::<f32>());
    assert_eq!(report.metrics.jobs_executed, 3);
}

#[test]
fn papers_search_max_walkthrough() {
    // §2.2: find the max of an array via chunked sub-maxima.
    let data: Vec<f32> = (0..1000).map(|i| ((i * 37 % 991) as f32) - 500.0).collect();
    let want = data.iter().cloned().fold(f32::MIN, f32::max);

    let mut reg = FunctionRegistry::new();
    let d = std::sync::Arc::new(data);
    reg.register_plain(1, "load", move |_in, out| {
        // k = 10 chunks, as the paper's walkthrough describes.
        let whole = DataChunk::from_f32(d.to_vec());
        for c in whole.split(10) {
            out.push(c);
        }
        Ok(())
    });
    reg.register_per_chunk_try(2, "search_max", |c| {
        Ok(DataChunk::scalar_f32(
            c.as_f32()?.iter().cloned().fold(f32::MIN, f32::max),
        ))
    });

    let algo = Algorithm::parse(
        "J1(1,1,0);
         J2(2,2,R1[0..5]), J3(2,2,R1[5..10]);
         J4(2,1,R2 R3);",
    )
    .unwrap();
    let report = fw(2, 2, reg).run(algo).unwrap();
    let result = report.result(4).unwrap();
    let got = result
        .chunks()
        .iter()
        .map(|c| c.first_f32().unwrap())
        .fold(f32::MIN, f32::max);
    assert_eq!(got, want);
}

fn big_consume_registry() -> FunctionRegistry {
    let mut r = FunctionRegistry::new();
    r.register_plain(1, "big", |_in, out| {
        out.push(DataChunk::from_f32(vec![1.0; 1 << 18])); // 1 MiB
        Ok(())
    });
    r.register_plain(2, "consume", |input, out| {
        let s = input.chunk(0)?.as_f32()?;
        out.push(DataChunk::scalar_f32(s.iter().sum::<f32>()));
        Ok(())
    });
    r
}

#[test]
fn keep_results_zero_transfer_consumption() {
    // J1 keeps a large result on its worker; J2 consumes it (pinned to the
    // same worker) — the payload must not cross the comm layer.
    let kept = fw(1, 2, big_consume_registry())
        .run(Algorithm::parse("J1(1,1,0,true); J2(2,1,R1);").unwrap())
        .unwrap();
    let not_kept = fw(1, 2, big_consume_registry())
        .run(Algorithm::parse("J1(1,1,0,false); J2(2,1,R1);").unwrap())
        .unwrap();

    assert_eq!(
        kept.result(2).unwrap().chunk(0).unwrap().first_f32().unwrap(),
        (1 << 18) as f32
    );
    assert!(
        kept.metrics.comm_bytes * 4 < not_kept.metrics.comm_bytes,
        "kept {} B vs not-kept {} B",
        kept.metrics.comm_bytes,
        not_kept.metrics.comm_bytes
    );
}

#[test]
fn thread_packing_runs_jobs_concurrently() {
    // Two 2-thread sleep jobs on one 4-core worker (paper §3.3's example):
    // wall time must be well under 2x the sleep.
    let mut reg = FunctionRegistry::new();
    reg.register_plain(1, "sleep50", |_in, _out| {
        std::thread::sleep(std::time::Duration::from_millis(50));
        Ok(())
    });
    let report = fw(1, 1, reg)
        .run(Algorithm::parse("J1(1,2,0), J2(1,2,0);").unwrap())
        .unwrap();
    assert_eq!(report.metrics.workers_spawned, 1);
    assert!(
        report.metrics.wall_time_us < 95_000,
        "packing failed: {} us",
        report.metrics.wall_time_us
    );
}

#[test]
fn per_chunk_distribution_across_sequences() {
    // One 4-thread job over 8 chunks each sleeping 20 ms: sequential would
    // be 160 ms, 4 sequences should land well under that.
    let mut reg = FunctionRegistry::new();
    reg.register_plain(1, "emit8", |_in, out| {
        for i in 0..8 {
            out.push(DataChunk::scalar_f32(i as f32));
        }
        Ok(())
    });
    reg.register_per_chunk(2, "slowid", |c| {
        std::thread::sleep(std::time::Duration::from_millis(20));
        c.clone()
    });
    let report = fw(1, 1, reg)
        .run(Algorithm::parse("J1(1,1,0); J2(2,4,R1);").unwrap())
        .unwrap();
    assert_eq!(report.result(2).unwrap().len(), 8);
    assert!(
        report.metrics.wall_time_us < 150_000,
        "sequences not parallel: {} us",
        report.metrics.wall_time_us
    );
}

#[test]
fn dynamic_injection_iterates_to_completion() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;
    let count = Arc::new(AtomicUsize::new(0));
    let c2 = count.clone();
    let mut reg = FunctionRegistry::new();
    reg.register_with_ctx(1, "self_injecting", move |_in, out, ctx| {
        let n = c2.fetch_add(1, Ordering::SeqCst) + 1;
        out.push(DataChunk::scalar_i32(n as i32));
        if n < 5 {
            ctx.inject(
                1,
                vec![InjectedJob {
                    local_id: 0,
                    func: FuncId(1),
                    threads: ThreadCount::Exact(1),
                    inputs: vec![],
                    keep: false,
                }],
            );
        }
        Ok(())
    });
    let report = fw(2, 2, reg)
        .run(Algorithm::parse("J1(1,1,0);").unwrap())
        .unwrap();
    assert_eq!(count.load(Ordering::SeqCst), 5);
    assert_eq!(report.metrics.jobs_executed, 5);
    assert_eq!(report.metrics.jobs_injected, 4);
    // Final segment holds the last injected job's result.
    let (_, data) = report.results.iter().next_back().unwrap();
    assert_eq!(data.chunk(0).unwrap().first_i32().unwrap(), 5);
}

#[test]
fn framework_jacobi_matches_sequential_rust_path() {
    for (schedulers, procs) in [(1usize, 1usize), (1, 2), (2, 4)] {
        let cfg = JacobiConfig::new(96, procs, 20);
        let seq = solvers::jacobi_seq(&cfg);
        let topo = jacobi_fw::FwTopology { schedulers, cores_per_worker: 4 };
        let (out, metrics) = jacobi_fw::run(&cfg, &topo).unwrap();
        assert_eq!(out.x.len(), seq.x.len());
        for (i, (a, b)) in out.x.iter().zip(&seq.x).enumerate() {
            assert_eq!(a, b, "x[{i}] diverged (s={schedulers}, p={procs})");
        }
        // 20 iterations -> 19 injected rounds of (p sweeps + 1 assemble).
        assert_eq!(metrics.jobs_injected, 19 * (procs + 1));
    }
}

#[test]
fn framework_jacobi_converges() {
    let cfg = JacobiConfig::new(96, 2, 150);
    let (out, _) = jacobi_fw::run(&cfg, &jacobi_fw::FwTopology::default()).unwrap();
    assert!(out.error_vs(&cfg) < 1e-3, "err {}", out.error_vs(&cfg));
    assert!(out.res_norm < 1e-2);
}

#[test]
fn framework_heat_matches_sequential() {
    let cfg = heat::HeatConfig::new(24, 16, 4, 6);
    let want = heat::heat_seq(&cfg);
    let (got, metrics) = heat::run(&cfg, 2).unwrap();
    assert_eq!(got.len(), want.len());
    for (i, (a, b)) in got.iter().zip(&want).enumerate() {
        assert!((a - b).abs() < 1e-5, "field[{i}]: {a} vs {b}");
    }
    // 1 params + 4 init + 6 steps x (4 edges + 4 steps)
    assert_eq!(metrics.jobs_executed, 1 + 4 + 6 * 8);
}

#[test]
fn lagged_release_policy_still_solves_jacobi() {
    let cfg = JacobiConfig::new(64, 2, 12);
    let registry = jacobi_fw::build_registry(&cfg).unwrap();
    let algo = jacobi_fw::build_algorithm(&cfg).unwrap();
    let fw = Framework::builder()
        .schedulers(2)
        .workers_per_scheduler(3)
        .registry(registry)
        .release_policy(ReleasePolicy::Lagged { lag: 3 })
        .build()
        .unwrap();
    let report = fw.run(algo).unwrap();
    let seq = solvers::jacobi_seq(&cfg);
    let (_, data) = report.results.iter().next_back().unwrap();
    let x = data.chunk(0).unwrap();
    assert_eq!(x.as_f32().unwrap(), seq.x.as_slice());
}

#[test]
fn both_modes_compute_identical_results() {
    // The dataflow executor must change the schedule, never the values.
    for mode in BOTH_MODES {
        let mut reg = FunctionRegistry::new();
        reg.register_plain(1, "emit", |_in, out| {
            out.push(DataChunk::from_f32(vec![1.0, 2.0]));
            out.push(DataChunk::from_f32(vec![3.0, 4.0]));
            Ok(())
        });
        reg.register_per_chunk_try(2, "square", |c| {
            Ok(DataChunk::from_f32(c.as_f32()?.iter().map(|v| v * v).collect()))
        });
        reg.register_plain(3, "sum", |input, out| {
            let mut acc = 0.0f32;
            for c in input.chunks() {
                acc += c.as_f32()?.iter().sum::<f32>();
            }
            out.push(DataChunk::scalar_f32(acc));
            Ok(())
        });
        let report = Framework::builder()
            .schedulers(2)
            .workers_per_scheduler(2)
            .execution_mode(mode)
            .registry(reg)
            .build()
            .unwrap()
            .run(Algorithm::parse("J1(1,1,0); J2(2,0,R1); J3(3,1,R2);").unwrap())
            .unwrap();
        let total = report.result(3).unwrap().chunk(0).unwrap().first_f32().unwrap();
        assert_eq!(total, 1.0 + 4.0 + 9.0 + 16.0, "mode {mode}");
        assert_eq!(report.metrics.jobs_executed, 3, "mode {mode}");
    }
}

#[test]
fn dataflow_overlaps_segments_where_barrier_cannot() {
    // Lane A's stage-0 job straggles 80 ms; lane B's chain is fast.  The
    // dataflow executor must assign B's stage-1 job while A's stage-0 job
    // is still in flight (pipeline overlap > 0); barriers never can.
    let mk = |mode: ExecutionMode| {
        let mut reg = FunctionRegistry::new();
        reg.register_plain(1, "straggler", |_in, out| {
            std::thread::sleep(std::time::Duration::from_millis(80));
            out.push(DataChunk::scalar_f32(1.0));
            Ok(())
        });
        reg.register_plain(2, "fast", |_in, out| {
            out.push(DataChunk::scalar_f32(2.0));
            Ok(())
        });
        reg.register_plain(3, "chain", |input, out| {
            out.push(DataChunk::scalar_f32(
                input.chunk(0)?.first_f32()? + 10.0,
            ));
            Ok(())
        });
        Framework::builder()
            .schedulers(2)
            .workers_per_scheduler(2)
            .execution_mode(mode)
            .registry(reg)
            .build()
            .unwrap()
            .run(
                Algorithm::parse(
                    "J1(1,1,0), J2(2,1,0);
                     J3(3,1,R2);
                     J4(3,1,R3), J5(3,1,R1);",
                )
                .unwrap(),
            )
            .unwrap()
    };
    let barrier = mk(ExecutionMode::Barrier);
    let dataflow = mk(ExecutionMode::Dataflow);
    for report in [&barrier, &dataflow] {
        assert_eq!(report.result(4).unwrap().chunk(0).unwrap().first_f32().unwrap(), 22.0);
        assert_eq!(report.result(5).unwrap().chunk(0).unwrap().first_f32().unwrap(), 11.0);
    }
    assert_eq!(barrier.metrics.pipeline_overlap_jobs, 0);
    assert!(
        dataflow.metrics.pipeline_overlap_jobs >= 1,
        "dataflow never overlapped segments (J1 straggles 80 ms while the \
         J2->J3->J4 chain should run through)"
    );
}

#[test]
fn lagged_release_keeps_results_alive_for_injections() {
    // Satellite regression (ISSUE 1): a runtime-injected job references a
    // result exactly `lag` segments behind its target segment.  Under
    // ReleasePolicy::Lagged { lag } that result must still be alive when
    // the injected job runs — the producer executes exactly once (a
    // premature release would force a recovery recompute) — and the run
    // completes with the right value in both execution modes.
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;
    for mode in BOTH_MODES {
        let produce_calls = Arc::new(AtomicUsize::new(0));
        let pc = produce_calls.clone();
        let mut reg = FunctionRegistry::new();
        reg.register_plain(4, "filler", |_in, out| {
            out.push(DataChunk::scalar_f32(0.0));
            Ok(())
        });
        reg.register_plain(1, "produce", move |_in, out| {
            pc.fetch_add(1, Ordering::SeqCst);
            out.push(DataChunk::scalar_f32(21.0));
            Ok(())
        });
        reg.register_with_ctx(2, "injector", |_in, out, ctx| {
            out.push(DataChunk::scalar_f32(0.0));
            // Target segment = injector's + 1 = 3; references R1 from
            // segment 1 — exactly lag = 2 segments back.
            ctx.inject(
                1,
                vec![InjectedJob {
                    local_id: 0,
                    func: FuncId(3),
                    threads: ThreadCount::Exact(1),
                    inputs: vec![InjectedRef::Existing(ChunkRef::all(JobId(1)))],
                    keep: false,
                }],
            );
            Ok(())
        });
        reg.register_plain(3, "double", |input, out| {
            out.push(DataChunk::scalar_f32(input.chunk(0)?.first_f32()? * 2.0));
            Ok(())
        });
        // Segments: 0 filler | 1 produce | 2 injector | 3 filler (+injected)
        let algo = Algorithm::parse(
            "J9(4,1,0);
             J1(1,1,0);
             J2(2,1,0);
             J3(4,1,0);",
        )
        .unwrap();
        let fw = Framework::builder()
            .schedulers(2)
            .workers_per_scheduler(2)
            .execution_mode(mode)
            .release_policy(ReleasePolicy::Lagged { lag: 2 })
            .registry(reg)
            .build()
            .unwrap();
        let report = fw.run(algo).unwrap();
        assert_eq!(
            produce_calls.load(Ordering::SeqCst),
            1,
            "mode {mode}: producer recomputed — its result was freed before \
             the injected consumer ran"
        );
        assert_eq!(report.metrics.jobs_injected, 1, "mode {mode}");
        // The injected job got the first id above the static maximum (10);
        // its doubled value must be in the final segment's results.
        let injected = report
            .result(10)
            .expect("injected job result in final segment")
            .chunk(0)
            .unwrap()
            .first_f32()
            .unwrap();
        assert_eq!(injected, 42.0, "mode {mode}");
    }
}

#[test]
fn lagged_release_boundary_matches_across_modes() {
    // Chain J1→J2→J3→J4 (4 segments), lag 2.  R1's last use is segment 1,
    // so under the unified horizon arithmetic (`last + lag <= horizon`,
    // DESIGN.md §6) it is freed exactly when the horizon reaches segment 3
    // — the barrier close of segment 3 / the dataflow frontier arriving
    // there — and it is the ONLY mid-run release: R2/R3's horizons lie
    // past the last segment and J4 is final.  Both modes must free at the
    // same lag distance (the dataflow executor used to be one segment
    // stricter and would release nothing here).
    for mode in BOTH_MODES {
        let mut reg = FunctionRegistry::new();
        reg.register_plain(1, "one", |_in, out| {
            out.push(DataChunk::scalar_f32(1.0));
            Ok(())
        });
        reg.register_plain(2, "inc", |input, out| {
            out.push(DataChunk::scalar_f32(input.chunk(0)?.first_f32()? + 1.0));
            Ok(())
        });
        let report = Framework::builder()
            .schedulers(2)
            .workers_per_scheduler(2)
            .execution_mode(mode)
            .release_policy(ReleasePolicy::Lagged { lag: 2 })
            .registry(reg)
            .build()
            .unwrap()
            .run(Algorithm::parse("J1(1,1,0); J2(2,1,R1); J3(2,1,R2); J4(2,1,R3);").unwrap())
            .unwrap();
        assert_eq!(
            report.result(4).unwrap().chunk(0).unwrap().first_f32().unwrap(),
            4.0,
            "mode {mode}"
        );
        assert_eq!(
            report.metrics.results_released, 1,
            "mode {mode}: exactly R1 must be freed at lag distance 2"
        );
    }
}

#[test]
fn unconsumed_result_survives_lag_window_for_injection() {
    // Satellite regression (ISSUE 2): a result with NO static consumers
    // used to anchor its barrier release horizon at segment 0 (missing
    // `last_use` defaulted to 0), so it was freed as soon as `lag`
    // segments closed — long before an injection referencing it exactly
    // `lag` segments after its producing segment could run.  The producing
    // segment must anchor the horizon: the producer executes exactly once.
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;
    for mode in BOTH_MODES {
        let produce_calls = Arc::new(AtomicUsize::new(0));
        let pc = produce_calls.clone();
        let mut reg = FunctionRegistry::new();
        reg.register_plain(4, "filler", |_in, out| {
            out.push(DataChunk::scalar_f32(0.0));
            Ok(())
        });
        reg.register_plain(1, "produce", move |_in, out| {
            pc.fetch_add(1, Ordering::SeqCst);
            out.push(DataChunk::scalar_f32(21.0));
            Ok(())
        });
        reg.register_with_ctx(2, "injector", |_in, out, ctx| {
            out.push(DataChunk::scalar_f32(0.0));
            // Target segment = injector's + 1 = 4; references R1 from
            // segment 2 — exactly lag = 2 segments back.
            ctx.inject(
                1,
                vec![InjectedJob {
                    local_id: 0,
                    func: FuncId(3),
                    threads: ThreadCount::Exact(1),
                    inputs: vec![InjectedRef::Existing(ChunkRef::all(JobId(1)))],
                    keep: false,
                }],
            );
            Ok(())
        });
        reg.register_plain(3, "double", |input, out| {
            out.push(DataChunk::scalar_f32(input.chunk(0)?.first_f32()? * 2.0));
            Ok(())
        });
        // Segments: 0 filler | 1 filler | 2 produce | 3 injector |
        // 4 filler (+ injected double).  J1's result has no static
        // consumer at all.
        let algo = Algorithm::parse(
            "J8(4,1,0);
             J9(4,1,0);
             J1(1,1,0);
             J2(2,1,0);
             J3(4,1,0);",
        )
        .unwrap();
        let report = Framework::builder()
            .schedulers(2)
            .workers_per_scheduler(2)
            .execution_mode(mode)
            .release_policy(ReleasePolicy::Lagged { lag: 2 })
            .registry(reg)
            .build()
            .unwrap()
            .run(algo)
            .unwrap();
        assert_eq!(
            produce_calls.load(Ordering::SeqCst),
            1,
            "mode {mode}: producer recomputed — its unconsumed result was \
             freed inside the lag window"
        );
        // The injected job doubles R1; its id is the first above the
        // static maximum (10) and it lands in the final segment.
        let injected = report
            .result(10)
            .expect("injected job result in final segment")
            .chunk(0)
            .unwrap()
            .first_f32()
            .unwrap();
        assert_eq!(injected, 42.0, "mode {mode}");
    }
}

#[test]
fn speculative_prefetch_warms_remote_inputs() {
    // J1 (8 KiB) and J2 (6 KiB) land on different schedulers (load
    // balancing); J3 straggles 120 ms.  J4 = f(R1, R2, R3): once J3 is its
    // only missing input, the master hints J4's probable target (J1's
    // owner, by byte affinity) to pull R2 across — by the time J3
    // finishes, R2 is warm in the target's store and the assignment
    // reports a prefetch hit.  With the knob off nothing is hinted, and
    // the computed values are identical either way.
    let run = |prefetch: bool| {
        let mut reg = FunctionRegistry::new();
        reg.register_plain(1, "big_a", |_in, out| {
            out.push(DataChunk::from_f32(vec![1.0; 2048])); // 8 KiB
            Ok(())
        });
        reg.register_plain(2, "big_b", |_in, out| {
            out.push(DataChunk::from_f32(vec![2.0; 1536])); // 6 KiB
            Ok(())
        });
        reg.register_plain(3, "straggler", |_in, out| {
            std::thread::sleep(std::time::Duration::from_millis(120));
            out.push(DataChunk::scalar_f32(3.0));
            Ok(())
        });
        reg.register_plain(4, "join", |input, out| {
            let mut acc = 0.0f32;
            for c in input.chunks() {
                acc += c.as_f32()?.iter().sum::<f32>();
            }
            out.push(DataChunk::scalar_f32(acc));
            Ok(())
        });
        Framework::builder()
            .schedulers(2)
            .workers_per_scheduler(2)
            .cores_per_worker(4)
            .execution_mode(ExecutionMode::Dataflow)
            .speculative_prefetch(prefetch)
            .registry(reg)
            .build()
            .unwrap()
            .run(
                Algorithm::parse("J1(1,1,0), J2(2,1,0), J3(3,1,0); J4(4,1,R1 R2 R3);")
                    .unwrap(),
            )
            .unwrap()
    };
    let on = run(true);
    let off = run(false);
    let want = 2048.0 + 2.0 * 1536.0 + 3.0;
    for (report, label) in [(&on, "on"), (&off, "off")] {
        assert_eq!(
            report.result(4).unwrap().chunk(0).unwrap().first_f32().unwrap(),
            want,
            "prefetch {label}: values must not depend on the knob"
        );
    }
    assert!(on.metrics.prefetches_sent >= 1, "no prefetch hint sent");
    assert!(
        on.metrics.prefetch_hits >= 1,
        "prefetched input not warm at assignment (sent {})",
        on.metrics.prefetches_sent
    );
    assert_eq!(off.metrics.prefetches_sent, 0, "knob off must disable hints");
    assert_eq!(off.metrics.prefetch_hits, 0);
}

#[test]
fn kept_prefetch_warms_worker_cache_and_off_is_inert() {
    // Same shape as `speculative_prefetch_warms_remote_inputs`, with
    // comm-aware placement on: besides landing in the predicted target's
    // *store*, the prefetched remote input is pushed into the predicted
    // *worker's* retained cache (`CachePush`), so the eventual dispatch
    // references it as a kept input and ships zero bytes for it
    // (DESIGN.md §10).  With `comm_aware_placement` off the kept-prefetch
    // layer is fully inert and values are identical.
    let run = |comm_aware: bool| {
        let mut reg = FunctionRegistry::new();
        reg.register_plain(1, "big_a", |_in, out| {
            out.push(DataChunk::from_f32(vec![1.0; 2048])); // 8 KiB
            Ok(())
        });
        reg.register_plain(2, "big_b", |_in, out| {
            out.push(DataChunk::from_f32(vec![2.0; 1536])); // 6 KiB
            Ok(())
        });
        reg.register_plain(3, "straggler", |_in, out| {
            std::thread::sleep(std::time::Duration::from_millis(120));
            out.push(DataChunk::scalar_f32(3.0));
            Ok(())
        });
        reg.register_plain(4, "join", |input, out| {
            let mut acc = 0.0f32;
            for c in input.chunks() {
                acc += c.as_f32()?.iter().sum::<f32>();
            }
            out.push(DataChunk::scalar_f32(acc));
            Ok(())
        });
        Framework::builder()
            .schedulers(2)
            .workers_per_scheduler(2)
            .cores_per_worker(4)
            .prespawn_workers(true) // hints must find a worker to warm
            .execution_mode(ExecutionMode::Dataflow)
            .speculative_prefetch(true)
            .comm_aware_placement(comm_aware)
            .registry(reg)
            .build()
            .unwrap()
            .run(
                Algorithm::parse("J1(1,1,0), J2(2,1,0), J3(3,1,0); J4(4,1,R1 R2 R3);")
                    .unwrap(),
            )
            .unwrap()
    };
    let on = run(true);
    let off = run(false);
    let want = 2048.0 + 2.0 * 1536.0 + 3.0;
    for (report, label) in [(&on, "on"), (&off, "off")] {
        assert_eq!(
            report.result(4).unwrap().chunk(0).unwrap().first_f32().unwrap(),
            want,
            "comm_aware {label}: values must not depend on the knob"
        );
    }
    assert!(
        on.metrics.kept_prefetch_pushes >= 1,
        "no CachePush sent (prefetches_sent {})",
        on.metrics.prefetches_sent
    );
    assert!(
        on.metrics.kept_prefetch_hits >= 1,
        "pushed copy not consumed as a kept input (pushes {})",
        on.metrics.kept_prefetch_pushes
    );
    // Calibration observed the run's traffic (on by default).
    assert!(on.metrics.comm_model.samples > 0, "comm model never calibrated");
    // Off = PR 4: the kept-prefetch layer never engages.
    assert_eq!(off.metrics.kept_prefetch_pushes, 0, "off must not push");
    assert_eq!(off.metrics.kept_prefetch_hits, 0);
    assert_eq!(off.metrics.kept_prefetch_cancels, 0);
}

#[test]
fn critical_path_metrics_cover_the_chain() {
    // A 3-job chain with measurable work: the critical path must span all
    // three jobs, its ideal equal the summed exec time, and its elapsed at
    // least that (ready→started→done spans are causally ordered).
    let mut reg = FunctionRegistry::new();
    reg.register_plain(1, "work", |_in, out| {
        std::thread::sleep(std::time::Duration::from_millis(10));
        out.push(DataChunk::scalar_f32(1.0));
        Ok(())
    });
    let report = fw(2, 2, reg)
        .run(Algorithm::parse("J1(1,1,0); J2(1,1,R1); J3(1,1,R2);").unwrap())
        .unwrap();
    let cp = report.metrics.critical_path();
    assert_eq!(cp.jobs, vec![1, 2, 3]);
    assert!(cp.ideal >= std::time::Duration::from_millis(30), "ideal {:?}", cp.ideal);
    assert!(cp.elapsed >= cp.ideal, "elapsed {:?} < ideal {:?}", cp.elapsed, cp.ideal);
}

#[test]
fn unknown_function_rejected_before_running() {
    let err = fw(1, 1, demo_registry())
        .run(Algorithm::parse("J1(77,1,0);").unwrap())
        .unwrap_err();
    assert!(matches!(err, hypar::Error::UnknownFunction(_)));
}

#[test]
fn failing_user_function_aborts_run() {
    let mut reg = FunctionRegistry::new();
    reg.register_plain(1, "boom", |_in, _out| {
        Err(hypar::Error::Assemble("deliberate failure".into()))
    });
    let err = fw(1, 1, reg)
        .run(Algorithm::parse("J1(1,1,0);").unwrap())
        .unwrap_err();
    match err {
        hypar::Error::JobFailed { job, msg } => {
            assert_eq!(job, JobId(1));
            assert!(msg.contains("deliberate failure"));
        }
        other => panic!("expected JobFailed, got {other}"),
    }
}

#[test]
fn panicking_user_function_fails_job_not_worker() {
    // Regression for the lock-poisoning panic path: a chunk that panics
    // must surface as a clean per-job failure (`ExecFailed` → `JobFailed`
    // at the master), not poison a pool lock or take the worker rank down
    // (which would show up as WorkerLost + recompute storms or a hang).
    let mut reg = FunctionRegistry::new();
    reg.register_plain(1, "emit", |_in, out| {
        out.push(DataChunk::from_f32(vec![1.0]));
        out.push(DataChunk::from_f32(vec![2.0]));
        out.push(DataChunk::from_f32(vec![3.0]));
        Ok(())
    });
    reg.register_per_chunk_try(2, "boom", |c| {
        if c.first_f32()? > 1.5 {
            panic!("chunk detonated");
        }
        Ok(c.clone())
    });
    // threads=2 on a 4-core worker: the packed (pool) path.
    let err = fw(1, 1, reg)
        .run(Algorithm::parse("J1(1,1,0); J2(2,2,R1);").unwrap())
        .unwrap_err();
    match err {
        hypar::Error::JobFailed { job, msg } => {
            assert_eq!(job, JobId(2));
            assert!(msg.contains("panicked"), "unexpected message: {msg}");
            assert!(msg.contains("chunk detonated"), "unexpected message: {msg}");
        }
        other => panic!("expected JobFailed, got {other}"),
    }
}

#[test]
fn panicking_plain_function_fails_cleanly_in_both_modes() {
    for mode in BOTH_MODES {
        let mut reg = FunctionRegistry::new();
        reg.register_plain(1, "kaboom", |_in, _out| -> Result<()> {
            panic!("plain detonated")
        });
        let err = Framework::builder()
            .schedulers(1)
            .workers_per_scheduler(1)
            .cores_per_worker(4)
            .execution_mode(mode)
            .registry(reg)
            .build()
            .unwrap()
            .run(Algorithm::parse("J1(1,1,0);").unwrap())
            .unwrap_err();
        match err {
            hypar::Error::JobFailed { job, msg } => {
                assert_eq!(job, JobId(1), "mode {mode}");
                assert!(msg.contains("panicked"), "mode {mode}: {msg}");
            }
            other => panic!("mode {mode}: expected JobFailed, got {other}"),
        }
    }
}

#[test]
fn work_stealing_knob_produces_identical_values() {
    // The paper-faithful static split must stay available and agree with
    // the stealing pool bit-for-bit; with stealing off, no steal may ever
    // be recorded.
    let build = |ws: bool| {
        let mut reg = FunctionRegistry::new();
        reg.register_plain(1, "emit", |_in, out| {
            for c in 0..12 {
                out.push(DataChunk::from_f32(
                    (0..6).map(|i| (c * 6 + i) as f32 * 0.25).collect(),
                ));
            }
            Ok(())
        });
        reg.register_per_chunk_try(2, "xform", |c| {
            Ok(DataChunk::from_f32(
                c.as_f32()?.iter().map(|v| v * 2.0 + 1.0).collect(),
            ))
        });
        Framework::builder()
            .schedulers(1)
            .workers_per_scheduler(1)
            .cores_per_worker(4)
            .work_stealing(ws)
            .registry(reg)
            .build()
            .unwrap()
    };
    let algo = || Algorithm::parse("J1(1,1,0); J2(2,0,R1);").unwrap();
    let on = build(true).run(algo()).unwrap();
    let off = build(false).run(algo()).unwrap();
    let flat = |r: &RunReport| -> Vec<f32> {
        r.result(2)
            .unwrap()
            .chunks()
            .iter()
            .flat_map(|c| c.as_f32().unwrap().iter().copied())
            .collect()
    };
    assert_eq!(flat(&on), flat(&off));
    assert_eq!(on.result(2).unwrap().len(), off.result(2).unwrap().len());
    assert_eq!(
        off.metrics.seq_steals, 0,
        "static split must never steal"
    );
    assert!(off.metrics.pool_jobs >= 1, "pool job metrics missing");
}

#[test]
fn chunk_range_out_of_bounds_is_reported() {
    // J1 emits 2 chunks; J2 asks for chunks 0..5.
    let mut reg = FunctionRegistry::new();
    reg.register_plain(1, "two", |_in, out| {
        out.push(DataChunk::scalar_f32(1.0));
        out.push(DataChunk::scalar_f32(2.0));
        Ok(())
    });
    reg.register_per_chunk(2, "id", |c| c.clone());
    let err = fw(1, 1, reg)
        .run(Algorithm::parse("J1(1,1,0); J2(2,1,R1[0..5]);").unwrap())
        .unwrap_err();
    assert!(
        matches!(
            err,
            hypar::Error::ResultNotAvailable(_) | hypar::Error::JobFailed { .. }
        ),
        "got {err}"
    );
}

#[test]
fn many_schedulers_many_small_jobs() {
    // Scheduling stress: 3 schedulers, 40 independent jobs in one segment.
    let mut reg = FunctionRegistry::new();
    reg.register_plain(1, "tiny", |_in, out| {
        out.push(DataChunk::scalar_f32(1.0));
        Ok(())
    });
    let jobs: Vec<String> = (1..=40).map(|i| format!("J{i}(1,1,0)")).collect();
    let script = format!("{};", jobs.join(", "));
    let report = fw(3, 4, reg).run(Algorithm::parse(&script).unwrap()).unwrap();
    assert_eq!(report.metrics.jobs_executed, 40);
    assert_eq!(report.results.len(), 40);
}
