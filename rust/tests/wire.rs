//! Adversarial wire-framing tests: seeded random [`FwMsg`] traffic pushed
//! through *real* loopback sockets under hostile stream conditions —
//! split writes, tiny partial reads, back-to-back frames in one write,
//! multi-megabyte payloads, truncated streams (DESIGN.md §15).
//!
//! The frame *layout* itself (length prefix, `wire_size` accounting) is
//! pinned by the unit tests inside `comm::wire`; this suite checks the
//! framing survives what a kernel socket actually does to a byte stream.

use std::io::{BufReader, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::time::Duration;

use hypar::comm::wire::{read_frame, write_frame, WirePayload, WireReader};
use hypar::comm::Rank;
use hypar::data::{DataChunk, FunctionData};
use hypar::job::{ChunkRange, ChunkRef, JobId, JobSpec, ThreadCount};
use hypar::scheduler::{ExecRequest, FwMsg, InputPart, SourceLoc};
use hypar::util::rng::Rng;

fn random_data(rng: &mut Rng, max_elems: usize) -> FunctionData {
    let n = rng.int_in(0, max_elems);
    FunctionData::from_chunks(vec![
        DataChunk::from_f64((0..n).map(|_| rng.f64()).collect()),
        DataChunk::from_i32(vec![rng.next_u64() as i32]),
    ])
}

fn random_spec(rng: &mut Rng) -> JobSpec {
    JobSpec::new(rng.next_u64() as u32, rng.next_u64() as u32, 2).with_inputs(vec![
        ChunkRef::all(JobId(rng.next_u64() as u32)),
        ChunkRef::slice(JobId(1), rng.int_in(0, 4), rng.int_in(5, 9)),
    ])
}

/// One random control message; weighted towards the payload-bearing and
/// nested variants because those stress the framing hardest.
fn random_msg(rng: &mut Rng, depth: usize) -> FwMsg {
    match rng.below(if depth == 0 { 8 } else { 7 }) {
        0 => FwMsg::Heartbeat,
        1 => FwMsg::ReleaseResult { job: JobId(rng.next_u64() as u32) },
        2 => FwMsg::JobError {
            job: JobId(rng.next_u64() as u32),
            msg: format!("err-{} — ünïcode", rng.next_u64()),
        },
        3 => FwMsg::Assign {
            spec: random_spec(rng),
            sources: vec![SourceLoc {
                job: JobId(rng.next_u64() as u32),
                owner: Rank(rng.next_u64() as u32),
                kept_on: if rng.bool() { Some(Rank(3)) } else { None },
            }],
        },
        4 => FwMsg::ResultData {
            job: JobId(rng.next_u64() as u32),
            data: random_data(rng, 64),
        },
        5 => FwMsg::Exec(ExecRequest {
            spec: random_spec(rng),
            input: vec![
                InputPart::Data(random_data(rng, 32)),
                InputPart::Kept {
                    job: JobId(rng.next_u64() as u32),
                    range: ChunkRange::Range { lo: 0, hi: rng.int_in(1, 9) },
                },
            ],
        }),
        6 => FwMsg::Prefetch {
            job: JobId(rng.next_u64() as u32),
            threads: if rng.bool() {
                ThreadCount::Auto
            } else {
                ThreadCount::Exact(rng.int_in(1, 8) as u32)
            },
            sources: vec![],
        },
        // Coalesced frame: members encode recursively into ONE socket frame.
        _ => FwMsg::Batch(
            (0..rng.int_in(1, 5)).map(|_| random_msg(rng, depth + 1)).collect(),
        ),
    }
}

fn frame_of(msg: &FwMsg) -> Vec<u8> {
    let mut body = Vec::new();
    msg.wire_encode(&mut body);
    let mut framed = Vec::new();
    write_frame(&mut framed, &body).unwrap();
    framed
}

fn decode_body(body: &[u8]) -> FwMsg {
    let mut r = WireReader::new(body);
    let msg = FwMsg::wire_decode(&mut r).unwrap();
    assert!(r.is_empty(), "frame body must decode exactly");
    msg
}

/// Spawn a server that reads frames until clean EOF and returns the
/// decoded messages' Debug forms (the equality oracle — `FwMsg`
/// intentionally has no `PartialEq`).
fn spawn_server(listener: TcpListener) -> std::thread::JoinHandle<Vec<String>> {
    std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        // A deliberately tiny buffer forces many short reads, so the
        // frame reassembly loop is exercised even when the client wrote
        // everything at once.
        let mut reader = BufReader::with_capacity(7, stream);
        let mut out = Vec::new();
        while let Some(body) = read_frame(&mut reader).unwrap() {
            out.push(format!("{:?}", decode_body(&body)));
        }
        out
    })
}

#[test]
fn frames_survive_split_writes_and_stalls() {
    let mut rng = Rng::new(0xC0FFEE);
    let msgs: Vec<FwMsg> = (0..64).map(|_| random_msg(&mut rng, 0)).collect();
    let expect: Vec<String> = msgs.iter().map(|m| format!("{m:?}")).collect();
    let stream_bytes: Vec<u8> = msgs.iter().flat_map(frame_of).collect();

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let port = listener.local_addr().unwrap().port();
    let server = spawn_server(listener);

    // Client: dribble the byte stream out in random 1–13 byte writes with
    // occasional stalls — every frame boundary gets split eventually.
    let mut client = TcpStream::connect(("127.0.0.1", port)).unwrap();
    client.set_nodelay(true).unwrap();
    let mut off = 0;
    while off < stream_bytes.len() {
        let n = rng.int_in(1, 13).min(stream_bytes.len() - off);
        client.write_all(&stream_bytes[off..off + n]).unwrap();
        client.flush().unwrap();
        off += n;
        if rng.below(16) == 0 {
            std::thread::sleep(Duration::from_millis(1));
        }
    }
    client.shutdown(Shutdown::Write).unwrap();

    assert_eq!(server.join().unwrap(), expect);
}

#[test]
fn back_to_back_frames_in_one_write() {
    let mut rng = Rng::new(42);
    let msgs: Vec<FwMsg> = (0..32).map(|_| random_msg(&mut rng, 0)).collect();
    let expect: Vec<String> = msgs.iter().map(|m| format!("{m:?}")).collect();
    let stream_bytes: Vec<u8> = msgs.iter().flat_map(frame_of).collect();

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let port = listener.local_addr().unwrap().port();
    let server = spawn_server(listener);

    let mut client = TcpStream::connect(("127.0.0.1", port)).unwrap();
    client.write_all(&stream_bytes).unwrap();
    drop(client);

    assert_eq!(server.join().unwrap(), expect);
}

#[test]
fn multi_megabyte_payload_rides_one_frame() {
    // 1M f64 elements ≈ 8 MB in a single frame, book-ended by small
    // frames so a length-accounting slip on the big one shears the next.
    let big = FwMsg::ResultData {
        job: JobId(7),
        data: FunctionData::from_chunks(vec![DataChunk::from_f64(
            (0..1_000_000).map(|i| i as f64 * 0.5).collect(),
        )]),
    };
    let msgs = vec![FwMsg::Heartbeat, big, FwMsg::HeartbeatAck];
    let expect: Vec<String> = msgs.iter().map(|m| format!("{m:?}")).collect();

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let port = listener.local_addr().unwrap().port();
    let server = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        let mut reader = BufReader::new(stream);
        let mut out = Vec::new();
        while let Some(body) = read_frame(&mut reader).unwrap() {
            out.push(format!("{:?}", decode_body(&body)));
        }
        out
    });

    let client = TcpStream::connect(("127.0.0.1", port)).unwrap();
    let mut writer = std::io::BufWriter::new(client);
    for m in &msgs {
        let mut body = Vec::new();
        m.wire_encode(&mut body);
        write_frame(&mut writer, &body).unwrap();
    }
    writer.flush().unwrap();
    drop(writer);

    assert_eq!(server.join().unwrap(), expect);
}

#[test]
fn truncated_stream_is_an_error_not_a_hang() {
    // A frame cut off mid-body must surface as UnexpectedEof; a clean
    // close between frames is Ok(None).  Pin both on a real socket.
    let mut body = Vec::new();
    FwMsg::JobError { job: JobId(1), msg: "half".into() }.wire_encode(&mut body);
    let mut framed = Vec::new();
    write_frame(&mut framed, &body).unwrap();

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let port = listener.local_addr().unwrap().port();
    let server = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        let mut reader = BufReader::new(stream);
        let first = read_frame(&mut reader).unwrap().expect("intact frame");
        let _ = decode_body(&first);
        read_frame(&mut reader)
    });

    let mut client = TcpStream::connect(("127.0.0.1", port)).unwrap();
    client.write_all(&framed).unwrap(); // one intact frame...
    client.write_all(&framed[..framed.len() - 3]).unwrap(); // ...one sheared
    drop(client);

    let err = server.join().unwrap().unwrap_err();
    assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof);
}
