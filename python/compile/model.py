"""L2: jax-level compute graphs for the framework's user functions.

These are the functions that get AOT-lowered to HLO text and executed from
the rust coordinator (L3) via PJRT.  Each comes in two variants:

* ``*_pallas`` — calls the L1 Pallas kernels (``kernels/jacobi.py``,
  ``kernels/heat.py``), the TPU-shaped hot path.
* ``*_ref``    — the pure-jnp formulation, used both as the build-time
  oracle and as a fast CPU execution path for the large Figure-3 sweeps
  (interpret-mode Pallas lowers to an HLO while-loop which is slower on
  the CPU backend; both variants are bit-compared in the test suite, so
  the coordination measurements are unaffected by which one runs).

Every function is shape-monomorphic at lowering time; ``aot.py`` emits one
artifact per (function, shape) config listed in its config table.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import heat as heat_k
from .kernels import jacobi as jacobi_k
from .kernels import ref


# --------------------------------------------------------------------------
# Jacobi: one step for a row block.
#   inputs : a_blk (bm,n) f32, x (n,) f32, b_blk (bm,) f32,
#            invdiag_blk (bm,) f32, row_offset () i32
#   outputs: x_blk_new (bm,) f32, res2 (1,) f32
# --------------------------------------------------------------------------

def jacobi_block_step_pallas(a_blk, x, b_blk, invdiag_blk, row_offset,
                             *, block_n: int):
    r_blk = jacobi_k.residual_block(a_blk, x, b_blk, block_n=block_n)
    bm = a_blk.shape[0]
    x_blk = jax.lax.dynamic_slice(x, (row_offset,), (bm,))
    return jacobi_k.update_block(x_blk, r_blk, invdiag_blk)


def jacobi_block_step_ref(a_blk, x, b_blk, invdiag_blk, row_offset):
    return ref.jacobi_block_step(a_blk, x, b_blk, invdiag_blk, row_offset)


# --------------------------------------------------------------------------
# Jacobi: monolithic full step (single-worker / validation artifact).
#   inputs : a (n,n), x (n,), b (n,), invdiag (n,)
#   outputs: x_new (n,), res2 (1,)
# --------------------------------------------------------------------------

def jacobi_full_step(a, x, b, invdiag):
    r = b - a @ x
    x_new = x + r * invdiag
    return x_new, jnp.sum(r * r).reshape((1,))


# --------------------------------------------------------------------------
# Heat: one explicit stencil step on a halo strip.
#   inputs : u_strip (rows,w) f32, alpha () f32
#   outputs: u_new (rows-2,w) f32
# --------------------------------------------------------------------------

def heat_strip_step_pallas(u_strip, alpha):
    return (heat_k.heat_strip_step(u_strip, alpha),)


def heat_strip_step_ref(u_strip, alpha):
    return (ref.heat_strip_step(u_strip, alpha),)


# --------------------------------------------------------------------------
# Dot-product block (used by the CG extension): partial <u, v>.
# --------------------------------------------------------------------------

def dot_block(u_blk, v_blk):
    return (jnp.sum(u_blk * v_blk).reshape((1,)),)


# --------------------------------------------------------------------------
# AXPY block (CG): w = u + alpha * v.
# --------------------------------------------------------------------------

def axpy_block(u_blk, v_blk, alpha):
    return (u_blk + alpha * v_blk,)


# --------------------------------------------------------------------------
# Matvec block (CG): y_blk = a_blk @ x.
# --------------------------------------------------------------------------

def matvec_block(a_blk, x):
    return (a_blk @ x,)
