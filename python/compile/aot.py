"""AOT driver: lower every (function, shape) config to HLO **text** + manifest.

This is the only place python touches the pipeline; it runs at build time
(``make artifacts``) and never on the request path.  For each config in the
tables below it

  1. jits + lowers the L2 function to stablehlo,
  2. converts to an XlaComputation and dumps **HLO text**
     (NOT ``.serialize()`` — jax >= 0.5 emits protos with 64-bit instruction
     ids which the rust side's xla_extension 0.5.1 rejects; the text parser
     reassigns ids and round-trips cleanly, see /opt/xla-example/README.md),
  3. numerically verifies the jitted function against the pure-jnp oracle
     on deterministic pseudo-random inputs,
  4. records the artifact in ``artifacts/manifest.json`` with its input /
     output shapes so the rust runtime can type-check feeds.

Usage: ``python -m compile.aot --out-dir ../artifacts [--quick]``
"""

from __future__ import annotations

import argparse
import functools
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref

BLOCK_N = 256  # column-tile width; all padded sizes are multiples of this

# Paper sizes (Figure 3) padded up to a multiple of BLOCK_N so one tile
# schedule serves every config; padding rows are identity rows (a_ii = 1,
# zero coupling, b_i = 0) so the mathematical solution is unchanged.
PAPER_SIZES = {2709: 2816, 4209: 4352, 7209: 7424}
WORKER_COUNTS = [1, 2, 4, 8]

TEST_N = 512            # small config for unit/integration tests + examples
HEAT_W = 256            # heat domain width (columns)
HEAT_H = 128            # heat interior rows
HEAT_TEST = (34, 64)    # small heat strip (rows, w) for tests


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _i32():
    return jax.ShapeDtypeStruct((), jnp.int32)


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _rng(seed):
    return np.random.default_rng(seed)


def _jacobi_inputs(n, bm, seed=0):
    """Deterministic diagonally-dominant block inputs for verification."""
    g = _rng(seed)
    a_blk = g.standard_normal((bm, n), dtype=np.float32) * 0.01
    row_offset = np.int32((n - bm) // 2 // 1)  # an interior, non-zero offset
    # strengthen this block's own diagonal entries
    for i in range(bm):
        a_blk[i, row_offset + i] = 4.0 + g.random()
    x = g.standard_normal((n,), dtype=np.float32)
    b_blk = g.standard_normal((bm,), dtype=np.float32)
    invdiag_blk = 1.0 / a_blk[np.arange(bm), row_offset + np.arange(bm)]
    return a_blk, x, b_blk, invdiag_blk.astype(np.float32), row_offset


class Builder:
    def __init__(self, out_dir):
        self.out_dir = out_dir
        self.manifest = {}
        self.t0 = time.time()

    def emit(self, name, fn, specs, *, kind, variant, params, verify):
        """Lower ``fn`` at ``specs``, verify numerics, write artifact."""
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = f"{name}.hlo.txt"
        with open(f"{self.out_dir}/{path}", "w") as f:
            f.write(text)

        got, want = verify(fn)
        for g, w in zip(got, want):
            np.testing.assert_allclose(
                np.asarray(g), np.asarray(w), rtol=2e-4, atol=2e-4,
                err_msg=f"artifact {name} disagrees with oracle",
            )

        out_shapes = [
            {"shape": list(o.shape), "dtype": str(o.dtype)} for o in got
        ]
        self.manifest[name] = {
            "file": path,
            "kind": kind,
            "variant": variant,
            "params": params,
            "inputs": [
                {"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs
            ],
            "outputs": out_shapes,
        }
        print(f"  [{time.time()-self.t0:6.1f}s] {name}", flush=True)

    # -- config families ----------------------------------------------------

    def jacobi_block(self, n, bm, variant):
        name = f"jacobi_block_{variant}_n{n}_bm{bm}"
        if variant == "pallas":
            fn = functools.partial(
                model.jacobi_block_step_pallas, block_n=BLOCK_N
            )
        else:
            fn = model.jacobi_block_step_ref
        specs = [_f32(bm, n), _f32(n), _f32(bm), _f32(bm), _i32()]

        def verify(fn):
            inp = _jacobi_inputs(n, bm)
            return fn(*inp), ref.jacobi_block_step(*inp)

        self.emit(name, fn, specs, kind="jacobi_block", variant=variant,
                  params={"n": n, "bm": bm, "block_n": BLOCK_N}, verify=verify)

    def jacobi_full(self, n):
        name = f"jacobi_full_n{n}"
        specs = [_f32(n, n), _f32(n), _f32(n), _f32(n)]

        def verify(fn):
            g = _rng(1)
            a = g.standard_normal((n, n), dtype=np.float32) * 0.01
            a[np.arange(n), np.arange(n)] = 4.0
            x = g.standard_normal((n,), dtype=np.float32)
            b = g.standard_normal((n,), dtype=np.float32)
            invd = (1.0 / np.diag(a)).astype(np.float32)
            r = b - a @ x
            return fn(a, x, b, invd), (x + r * invd, (r @ r).reshape(1))

        self.emit(name, model.jacobi_full_step, specs, kind="jacobi_full",
                  variant="ref", params={"n": n}, verify=verify)

    def heat_strip(self, rows, w, variant):
        name = f"heat_strip_{variant}_r{rows}_w{w}"
        fn = (model.heat_strip_step_pallas if variant == "pallas"
              else model.heat_strip_step_ref)
        specs = [_f32(rows, w), _f32()]

        def verify(fn):
            g = _rng(2)
            u = g.standard_normal((rows, w), dtype=np.float32)
            alpha = np.float32(0.2)
            return fn(u, alpha), (ref.heat_strip_step(u, alpha),)

        self.emit(name, fn, specs, kind="heat_strip", variant=variant,
                  params={"rows": rows, "w": w}, verify=verify)

    def cg_blocks(self, n, bm):
        g = _rng(3)
        u = g.standard_normal((bm,), dtype=np.float32)
        v = g.standard_normal((bm,), dtype=np.float32)
        a_blk = g.standard_normal((bm, n), dtype=np.float32)
        x = g.standard_normal((n,), dtype=np.float32)
        alpha = np.float32(0.7)

        self.emit(
            f"dot_block_bm{bm}", model.dot_block, [_f32(bm), _f32(bm)],
            kind="dot_block", variant="ref", params={"bm": bm},
            verify=lambda fn: (fn(u, v), ((u @ v).reshape(1),)),
        )
        self.emit(
            f"axpy_block_bm{bm}", model.axpy_block,
            [_f32(bm), _f32(bm), _f32()],
            kind="axpy_block", variant="ref", params={"bm": bm},
            verify=lambda fn: (fn(u, v, alpha), (u + alpha * v,)),
        )
        self.emit(
            f"matvec_block_n{n}_bm{bm}", model.matvec_block,
            [_f32(bm, n), _f32(n)],
            kind="matvec_block", variant="ref", params={"n": n, "bm": bm},
            verify=lambda fn: (fn(a_blk, x), (a_blk @ x,)),
        )


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="only the small test configs (dev loop)")
    args = ap.parse_args()

    b = Builder(args.out_dir)

    # Small configs: tests, quickstart, examples.
    for p in (1, 2, 4):
        bm = TEST_N // p
        for variant in ("pallas", "ref"):
            b.jacobi_block(TEST_N, bm, variant)
    b.jacobi_full(TEST_N)
    for variant in ("pallas", "ref"):
        b.heat_strip(*HEAT_TEST, variant)
    b.cg_blocks(TEST_N, TEST_N)
    b.cg_blocks(TEST_N, TEST_N // 2)

    if not args.quick:
        # Figure-3 configs: padded paper sizes x worker counts.
        for n in PAPER_SIZES.values():
            for p in WORKER_COUNTS:
                bm = n // p
                b.jacobi_block(n, bm, "ref")
        # Pallas variants at the smallest paper size (e2e example) — the
        # large interpret-mode artifacts exist to validate numerics, the
        # Figure-3 sweeps run the ref variant (see model.py docstring).
        for p in WORKER_COUNTS:
            b.jacobi_block(2816, 2816 // p, "pallas")
        # Heat production strips.
        for p in (1, 2, 4):
            rows = HEAT_H // p + 2
            for variant in ("pallas", "ref"):
                b.heat_strip(rows, HEAT_W, variant)

    manifest = {
        "block_n": BLOCK_N,
        "paper_sizes": {str(k): v for k, v in PAPER_SIZES.items()},
        "artifacts": b.manifest,
    }
    with open(f"{args.out_dir}/manifest.json", "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {len(b.manifest)} artifacts + manifest.json "
          f"to {args.out_dir} in {time.time()-b.t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
