"""Pallas kernel for the 2-D heat-diffusion stencil (engineering workload).

The paper motivates the framework with engineering simulation codes; the
heat example (``examples/heat_diffusion.rs``) parallelises an explicit
finite-difference heat solver through the framework's job model.  The
per-job hot-spot — one Jacobi-style 5-point stencil sweep over a horizontal
strip of the domain — is this kernel.

The strip carries one halo row on each side (exchanged between jobs by the
framework as chunk dependencies), so a ``(rows, w)`` strip input produces a
``(rows-2, w)`` interior update.  Columns 0 and w-1 are Dirichlet
boundaries and are copied through.

``interpret=True`` for CPU-PJRT executability; oracle in ``ref.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _heat_kernel(u_ref, alpha_ref, o_ref):
    """u' = u + alpha * laplace(u) over the strip interior."""
    u = u_ref[...]
    alpha = alpha_ref[0]
    centre = u[1:-1, 1:-1]
    lap = (
        u[:-2, 1:-1] + u[2:, 1:-1] + u[1:-1, :-2] + u[1:-1, 2:]
        - 4.0 * centre
    )
    interior = centre + alpha * lap
    # Re-attach the Dirichlet side columns of the interior rows.
    left = u[1:-1, 0:1]
    right = u[1:-1, -1:]
    o_ref[...] = jnp.concatenate([left, interior, right], axis=1)


def heat_strip_step(u_strip, alpha):
    """One explicit heat step on a halo-padded strip.

    Args:
      u_strip: ``(rows, w)`` strip including one halo row above and below.
      alpha: scalar ``dt*k/h^2`` diffusion number (stable for ``<= 0.25``).

    Returns: ``(rows-2, w)`` updated interior rows.
    """
    rows, w = u_strip.shape
    alpha_arr = jnp.asarray(alpha, jnp.float32).reshape((1,))
    return pl.pallas_call(
        _heat_kernel,
        out_shape=jax.ShapeDtypeStruct((rows - 2, w), jnp.float32),
        interpret=True,
    )(u_strip, alpha_arr)
