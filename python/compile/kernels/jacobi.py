"""Pallas kernels for the block-Jacobi sweep (L1 of the stack).

The paper's evaluation workload (its §4) is a parallel Jacobi solver for
``A·x = b``.  The compute hot-spot of one iteration, for the row block a
single framework job owns, is the residual sweep

    r_blk = b_blk - A_blk @ x                     (J1 in the paper)

followed by the diagonally-preconditioned update + partial residual norm

    x_blk' = x_blk + r_blk * invdiag_blk          (J2 in the paper)
    res2   = sum(r_blk^2)

Both are expressed here as Pallas kernels so they lower into the same HLO
module as the surrounding jax function (see ``model.py``) and run from the
rust coordinator via PJRT.

TPU shaping (see DESIGN.md §Hardware-Adaptation): the residual sweep tiles
the row block over column tiles of width ``block_n`` with a ``BlockSpec``
grid, so each ``(bm, block_n)`` tile of ``A`` streams HBM→VMEM exactly once
per sweep while the ``(bm,)`` accumulator stays resident in the output VMEM
ref across the column loop.  The matmul inside the tile targets the MXU.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret-mode lowers to plain HLO ops which run on any
backend.  Correctness is pinned against ``ref.py`` by ``python/tests``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _residual_kernel(a_ref, x_ref, b_ref, o_ref):
    """One column-tile step of ``o = b - A @ x`` for a row block.

    Grid dimension 0 walks the column tiles.  The output ref doubles as the
    VMEM-resident accumulator: initialised to ``b`` on the first tile, then
    decremented by each tile's partial product.
    """
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = b_ref[...]

    # (bm, bn) @ (bn,) partial product on the MXU; accumulate in f32.
    o_ref[...] -= jnp.dot(
        a_ref[...], x_ref[...], preferred_element_type=jnp.float32
    )


def residual_block(a_blk, x, b_blk, *, block_n: int = 512):
    """``r_blk = b_blk - a_blk @ x`` as a tiled Pallas call.

    Args:
      a_blk: ``(bm, n)`` row block of the system matrix.
      x: ``(n,)`` current iterate (full vector — every job needs all of x).
      b_blk: ``(bm,)`` right-hand-side slice for this row block.
      block_n: column-tile width (HBM→VMEM streaming granularity).

    ``n`` must be divisible by ``block_n``; the AOT driver pads upstream.
    """
    bm, n = a_blk.shape
    if n % block_n != 0:
        raise ValueError(f"n={n} not divisible by block_n={block_n}")
    grid = (n // block_n,)
    return pl.pallas_call(
        _residual_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, block_n), lambda j: (0, j)),
            pl.BlockSpec((block_n,), lambda j: (j,)),
            pl.BlockSpec((bm,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda j: (0,)),
        out_shape=jax.ShapeDtypeStruct((bm,), jnp.float32),
        interpret=True,
    )(a_blk, x, b_blk)


def _update_kernel(x_ref, r_ref, invd_ref, xo_ref, res_ref):
    """Fused Jacobi update + squared-residual partial reduction."""
    r = r_ref[...]
    xo_ref[...] = x_ref[...] + r * invd_ref[...]
    res_ref[0] = jnp.sum(r * r)


def update_block(x_blk, r_blk, invdiag_blk):
    """``(x_blk + r_blk*invdiag_blk, sum(r_blk^2))`` as a Pallas call."""
    (bm,) = x_blk.shape
    return pl.pallas_call(
        _update_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((bm,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ),
        interpret=True,
    )(x_blk, r_blk, invdiag_blk)


@functools.partial(jax.jit, static_argnames=("block_n",))
def jacobi_block_step(a_blk, x, b_blk, invdiag_blk, row_offset, *, block_n=512):
    """One full Jacobi step for a row block: residual sweep + update.

    ``row_offset`` is a traced scalar so one compiled artifact serves every
    block position of a given shape (the rust side passes the block's start
    row).  Returns ``(x_blk_new, res2_partial)``.
    """
    bm, _ = a_blk.shape
    r_blk = residual_block(a_blk, x, b_blk, block_n=block_n)
    x_blk = jax.lax.dynamic_slice(x, (row_offset,), (bm,))
    return update_block(x_blk, r_blk, invdiag_blk)
