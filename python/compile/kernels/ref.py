"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function here computes the same quantity as its namesake in
``jacobi.py`` / ``heat.py`` with plain jax.numpy ops, no Pallas.  The pytest
suite asserts allclose between kernel and oracle across swept shapes
(hypothesis) and the AOT driver re-checks the lowered HLO numerics once per
artifact build.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def residual_block(a_blk, x, b_blk):
    """``r_blk = b_blk - a_blk @ x`` (oracle)."""
    return b_blk - a_blk.astype(jnp.float32) @ x.astype(jnp.float32)


def update_block(x_blk, r_blk, invdiag_blk):
    """``(x_blk + r*invdiag, sum(r^2))`` (oracle)."""
    x_new = x_blk + r_blk * invdiag_blk
    res2 = jnp.sum(r_blk * r_blk).reshape((1,))
    return x_new, res2


def jacobi_block_step(a_blk, x, b_blk, invdiag_blk, row_offset):
    """One Jacobi step for a row block (oracle for the fused model fn)."""
    bm = a_blk.shape[0]
    r = residual_block(a_blk, x, b_blk)
    x_blk = jax.lax.dynamic_slice(x, (row_offset,), (bm,))
    return update_block(x_blk, r, invdiag_blk)


def heat_strip_step(u_strip, alpha):
    """One 5-point explicit heat step on a halo strip (oracle)."""
    u = u_strip
    centre = u[1:-1, 1:-1]
    lap = (
        u[:-2, 1:-1] + u[2:, 1:-1] + u[1:-1, :-2] + u[1:-1, 2:]
        - 4.0 * centre
    )
    interior = centre + alpha * lap
    return jnp.concatenate([u[1:-1, 0:1], interior, u[1:-1, -1:]], axis=1)


def jacobi_solve(a, b, iters):
    """Dense reference Jacobi (residual-correction form), for e2e checks."""
    invd = 1.0 / jnp.diag(a)
    x = jnp.zeros_like(b)
    for _ in range(iters):
        r = b - a @ x
        x = x + r * invd
    return x
