"""L1: Pallas kernels for the paper's compute hot-spots.

``jacobi``  — block residual sweep + diagonally-preconditioned update
              (the paper's §4 evaluation workload).
``heat``    — 5-point explicit heat-diffusion stencil on halo strips
              (engineering simulation workload from the paper's intro).
``ref``     — pure-jnp oracles for all of the above.
"""

from . import heat, jacobi, ref  # noqa: F401
