"""AOT pipeline tests: HLO text emission, manifest integrity, round-trip.

The round-trip test re-compiles the emitted HLO text with the *python* XLA
client and compares numerics — the same text the rust PJRT runtime loads,
so this is the strongest build-time signal that the interchange works.
"""

import json
import os
import subprocess
import sys
import tempfile

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import aot, model
from compile.kernels import ref


def test_to_hlo_text_smoke():
    lowered = jax.jit(lambda x: (x * 2.0,)).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text


def test_hlo_text_roundtrip_executes():
    """Emit HLO text -> parse it back -> compile -> run -> same numbers."""
    bm, n = 128, 512
    lowered = jax.jit(model.jacobi_block_step_ref).lower(
        jax.ShapeDtypeStruct((bm, n), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((bm,), jnp.float32),
        jax.ShapeDtypeStruct((bm,), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.int32),
    )
    text = aot.to_hlo_text(lowered)

    # Parse the text back into a computation and run it on the CPU client —
    # the same path the rust runtime takes.
    comp = xc.XlaComputation(
        xc._xla.hlo_module_proto_from_text(text).SerializeToString()
    ) if hasattr(xc._xla, "hlo_module_proto_from_text") else None
    if comp is None:
        pytest.skip("python xla_client lacks hlo-text parser; "
                    "covered by rust runtime tests")

    client = xc.make_cpu_client()
    exe = client.compile(comp)
    g = np.random.default_rng(0)
    a_blk = g.standard_normal((bm, n)).astype(np.float32)
    x = g.standard_normal(n).astype(np.float32)
    b_blk = g.standard_normal(bm).astype(np.float32)
    invd = (0.1 + g.random(bm)).astype(np.float32)
    off = np.int32(64)
    outs = exe.execute_sharded(
        [[client.buffer_from_pyval(v) for v in (a_blk, x, b_blk, invd, off)]]
    ) if hasattr(exe, "execute_sharded") else None
    if outs is None:
        pytest.skip("execute API mismatch; covered by rust runtime tests")
    got = [np.asarray(o[0]) for o in outs.disassemble_into_single_device_arrays()]
    want = ref.jacobi_block_step(a_blk, x, b_blk, invd, off)
    np.testing.assert_allclose(got[0], want[0], rtol=1e-4, atol=1e-4)


def test_jacobi_inputs_are_reproducible():
    a1 = aot._jacobi_inputs(512, 128)
    a2 = aot._jacobi_inputs(512, 128)
    for u, v in zip(a1, a2):
        np.testing.assert_array_equal(u, v)


def test_quick_build_writes_consistent_manifest(tmp_path):
    out = subprocess.run(
        [sys.executable, "-m", "compile.aot",
         "--out-dir", str(tmp_path), "--quick"],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True, text=True, timeout=600,
    )
    assert out.returncode == 0, out.stderr
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["block_n"] == aot.BLOCK_N
    arts = manifest["artifacts"]
    assert len(arts) >= 12
    for name, entry in arts.items():
        path = tmp_path / entry["file"]
        assert path.exists(), f"missing artifact file for {name}"
        text = path.read_text()
        assert "HloModule" in text
        assert entry["kind"] in {
            "jacobi_block", "jacobi_full", "heat_strip",
            "dot_block", "axpy_block", "matvec_block",
        }
        assert entry["inputs"] and entry["outputs"]
    # every advertised config is present
    assert "jacobi_block_pallas_n512_bm256" in arts
    assert "jacobi_full_n512" in arts
    assert arts["jacobi_block_ref_n512_bm128"]["params"]["bm"] == 128


def test_padded_system_preserves_solution():
    """Identity-row padding (the Figure-3 size trick) leaves x* unchanged."""
    n, n_pad = 100, 128
    g = np.random.default_rng(7)
    a = g.standard_normal((n, n)).astype(np.float32) * 0.05
    a[np.arange(n), np.arange(n)] = 4.0
    x_star = g.standard_normal(n).astype(np.float32)
    b = a @ x_star

    a_pad = np.eye(n_pad, dtype=np.float32)
    a_pad[:n, :n] = a
    b_pad = np.zeros(n_pad, dtype=np.float32)
    b_pad[:n] = b

    x_pad = np.asarray(ref.jacobi_solve(jnp.array(a_pad), jnp.array(b_pad), 300))
    np.testing.assert_allclose(x_pad[:n], x_star, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(x_pad[n:], 0.0, atol=1e-6)
