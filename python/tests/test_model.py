"""L2 model-function tests: shapes, variants agree, CG blocks, full step."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def _rng(seed=0):
    return np.random.default_rng(seed)


def _block_case(bm, n, seed=0):
    g = _rng(seed)
    return (
        g.standard_normal((bm, n)).astype(np.float32),
        g.standard_normal(n).astype(np.float32),
        g.standard_normal(bm).astype(np.float32),
        (0.1 + g.random(bm)).astype(np.float32),
        np.int32((n - bm) // 2),
    )


@pytest.mark.parametrize("bm,n", [(128, 512), (256, 512), (512, 512)])
def test_pallas_and_ref_variants_agree(bm, n):
    case = _block_case(bm, n, seed=5)
    got_p = model.jacobi_block_step_pallas(
        *map(jnp.array, case[:4]), case[4], block_n=256)
    got_r = model.jacobi_block_step_ref(*map(jnp.array, case[:4]), case[4])
    np.testing.assert_allclose(got_p[0], got_r[0], rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(got_p[1], got_r[1], rtol=1e-3, atol=1e-2)


def test_block_step_output_shapes():
    case = _block_case(128, 512)
    x_new, res2 = model.jacobi_block_step_ref(
        *map(jnp.array, case[:4]), case[4])
    assert x_new.shape == (128,)
    assert res2.shape == (1,)


def test_full_step_matches_blockwise_composition():
    """The monolithic artifact == assembling the p block artifacts."""
    n, p = 512, 4
    bm = n // p
    g = _rng(9)
    a = g.standard_normal((n, n)).astype(np.float32) * 0.01
    a[np.arange(n), np.arange(n)] = 4.0
    x = g.standard_normal(n).astype(np.float32)
    b = g.standard_normal(n).astype(np.float32)
    invd = (1.0 / np.diag(a)).astype(np.float32)

    full_x, full_r2 = model.jacobi_full_step(
        jnp.array(a), jnp.array(x), jnp.array(b), jnp.array(invd))

    parts, r2 = [], 0.0
    for k in range(p):
        lo = k * bm
        xb, rb = model.jacobi_block_step_ref(
            jnp.array(a[lo:lo + bm]), jnp.array(x), jnp.array(b[lo:lo + bm]),
            jnp.array(invd[lo:lo + bm]), np.int32(lo))
        parts.append(np.asarray(xb))
        r2 += float(rb[0])

    np.testing.assert_allclose(
        np.concatenate(parts), full_x, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(r2, float(full_r2[0]), rtol=1e-3, atol=1e-2)


def test_iterated_block_steps_converge():
    """Driving the block artifacts in a loop solves the system (e2e-in-python
    mirror of what the rust coordinator does)."""
    n, p, bm = 512, 2, 256
    g = _rng(21)
    a = g.standard_normal((n, n)).astype(np.float32) * 0.02
    a[np.arange(n), np.arange(n)] = 4.0
    x_star = g.standard_normal(n).astype(np.float32)
    b = (a @ x_star).astype(np.float32)
    invd = (1.0 / np.diag(a)).astype(np.float32)

    x = np.zeros(n, dtype=np.float32)
    for _ in range(120):
        nxt = []
        for k in range(p):
            lo = k * bm
            xb, _ = model.jacobi_block_step_ref(
                jnp.array(a[lo:lo + bm]), jnp.array(x),
                jnp.array(b[lo:lo + bm]), jnp.array(invd[lo:lo + bm]),
                np.int32(lo))
            nxt.append(np.asarray(xb))
        x = np.concatenate(nxt)
    np.testing.assert_allclose(x, x_star, rtol=2e-3, atol=2e-3)


# ------------------------------------------------------------ CG blocks

def test_dot_block():
    g = _rng(2)
    u = g.standard_normal(256).astype(np.float32)
    v = g.standard_normal(256).astype(np.float32)
    (got,) = model.dot_block(jnp.array(u), jnp.array(v))
    np.testing.assert_allclose(got, [u @ v], rtol=1e-4, atol=1e-3)


def test_axpy_block():
    g = _rng(3)
    u = g.standard_normal(64).astype(np.float32)
    v = g.standard_normal(64).astype(np.float32)
    (got,) = model.axpy_block(jnp.array(u), jnp.array(v), np.float32(0.5))
    np.testing.assert_allclose(got, u + 0.5 * v, rtol=1e-6, atol=1e-6)


def test_matvec_block():
    g = _rng(4)
    a = g.standard_normal((64, 512)).astype(np.float32)
    x = g.standard_normal(512).astype(np.float32)
    (got,) = model.matvec_block(jnp.array(a), jnp.array(x))
    np.testing.assert_allclose(got, a @ x, rtol=1e-3, atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(bm=st.integers(1, 128), seed=st.integers(0, 2**31 - 1),
       alpha=st.floats(-2.0, 2.0))
def test_axpy_block_hypothesis(bm, seed, alpha):
    g = _rng(seed)
    u = g.standard_normal(bm).astype(np.float32)
    v = g.standard_normal(bm).astype(np.float32)
    (got,) = model.axpy_block(jnp.array(u), jnp.array(v), np.float32(alpha))
    np.testing.assert_allclose(got, u + np.float32(alpha) * v,
                               rtol=1e-5, atol=1e-5)
