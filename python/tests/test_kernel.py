"""Kernel-vs-oracle correctness: the CORE numeric signal of the build.

Every Pallas kernel is compared against its pure-jnp oracle from
``kernels/ref.py``, both on fixed paper-relevant shapes and under
hypothesis-driven shape/value sweeps.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import heat, jacobi, ref

RTOL = 2e-4
ATOL = 2e-4


def _rng(seed=0):
    return np.random.default_rng(seed)


def _jacobi_case(bm, n, seed=0, offset=None):
    g = _rng(seed)
    a_blk = g.standard_normal((bm, n)).astype(np.float32)
    x = g.standard_normal(n).astype(np.float32)
    b_blk = g.standard_normal(bm).astype(np.float32)
    invd = (0.1 + g.random(bm)).astype(np.float32)
    if offset is None:
        offset = (n - bm) // 2
    return a_blk, x, b_blk, invd, np.int32(offset)


# ---------------------------------------------------------------- residual

@pytest.mark.parametrize("bm,n,block_n", [
    (1, 256, 256),
    (7, 256, 256),
    (64, 512, 256),
    (128, 512, 512),
    (352, 2816, 256),   # padded paper size 2709, p=8
])
def test_residual_block_matches_ref(bm, n, block_n):
    a_blk, x, b_blk, _, _ = _jacobi_case(bm, n)
    got = jacobi.residual_block(
        jnp.array(a_blk), jnp.array(x), jnp.array(b_blk), block_n=block_n)
    want = ref.residual_block(a_blk, x, b_blk)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_residual_block_rejects_indivisible_n():
    a_blk, x, b_blk, _, _ = _jacobi_case(4, 300)
    with pytest.raises(ValueError, match="not divisible"):
        jacobi.residual_block(
            jnp.array(a_blk), jnp.array(x), jnp.array(b_blk), block_n=256)


def test_residual_block_zero_matrix_returns_b():
    b_blk = np.arange(8, dtype=np.float32)
    got = jacobi.residual_block(
        jnp.zeros((8, 256)), jnp.ones((256,)), jnp.array(b_blk), block_n=256)
    np.testing.assert_allclose(got, b_blk, rtol=0, atol=0)


@settings(max_examples=25, deadline=None)
@given(
    bm=st.integers(1, 48),
    tiles=st.integers(1, 4),
    block_n=st.sampled_from([64, 128, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_residual_block_hypothesis(bm, tiles, block_n, seed):
    n = tiles * block_n
    a_blk, x, b_blk, _, _ = _jacobi_case(bm, n, seed=seed)
    got = jacobi.residual_block(
        jnp.array(a_blk), jnp.array(x), jnp.array(b_blk), block_n=block_n)
    want = ref.residual_block(a_blk, x, b_blk)
    # accumulation-order differences scale with n
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3 * np.sqrt(n))


# ------------------------------------------------------------------ update

@pytest.mark.parametrize("bm", [1, 5, 64, 352])
def test_update_block_matches_ref(bm):
    g = _rng(3)
    x_blk = g.standard_normal(bm).astype(np.float32)
    r_blk = g.standard_normal(bm).astype(np.float32)
    invd = (0.1 + g.random(bm)).astype(np.float32)
    gx, gr = jacobi.update_block(
        jnp.array(x_blk), jnp.array(r_blk), jnp.array(invd))
    wx, wr = ref.update_block(x_blk, r_blk, invd)
    np.testing.assert_allclose(gx, wx, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(gr, wr, rtol=1e-3, atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(bm=st.integers(1, 256), seed=st.integers(0, 2**31 - 1))
def test_update_block_hypothesis(bm, seed):
    g = _rng(seed)
    x_blk = g.standard_normal(bm).astype(np.float32)
    r_blk = g.standard_normal(bm).astype(np.float32)
    invd = (0.1 + g.random(bm)).astype(np.float32)
    gx, gr = jacobi.update_block(
        jnp.array(x_blk), jnp.array(r_blk), jnp.array(invd))
    wx, wr = ref.update_block(x_blk, r_blk, invd)
    np.testing.assert_allclose(gx, wx, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(gr, wr, rtol=1e-3, atol=1e-3 * bm)


def test_update_block_zero_residual_is_identity():
    x_blk = np.arange(16, dtype=np.float32)
    gx, gr = jacobi.update_block(
        jnp.array(x_blk), jnp.zeros(16), jnp.ones(16))
    np.testing.assert_allclose(gx, x_blk, rtol=0, atol=0)
    assert float(gr[0]) == 0.0


# ----------------------------------------------------------- fused step

@pytest.mark.parametrize("bm,n,offset", [
    (128, 512, 0),
    (128, 512, 128),
    (128, 512, 384),     # last block
    (512, 512, 0),       # single-block (p=1) layout
])
def test_jacobi_block_step_matches_ref(bm, n, offset):
    case = _jacobi_case(bm, n, seed=7, offset=offset)
    got = jacobi.jacobi_block_step(*map(jnp.array, case[:4]), case[4],
                                   block_n=256)
    want = ref.jacobi_block_step(*case)
    np.testing.assert_allclose(got[0], want[0], rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(got[1], want[1], rtol=1e-3, atol=1e-2)


# -------------------------------------------------------------------- heat

@pytest.mark.parametrize("rows,w", [(3, 4), (10, 16), (34, 64), (66, 256)])
def test_heat_strip_matches_ref(rows, w):
    g = _rng(11)
    u = g.standard_normal((rows, w)).astype(np.float32)
    got = heat.heat_strip_step(jnp.array(u), 0.2)
    want = ref.heat_strip_step(u, np.float32(0.2))
    assert got.shape == (rows - 2, w)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_heat_strip_preserves_dirichlet_columns():
    g = _rng(12)
    u = g.standard_normal((10, 8)).astype(np.float32)
    got = np.asarray(heat.heat_strip_step(jnp.array(u), 0.25))
    np.testing.assert_allclose(got[:, 0], u[1:-1, 0], rtol=0, atol=0)
    np.testing.assert_allclose(got[:, -1], u[1:-1, -1], rtol=0, atol=0)


def test_heat_strip_uniform_field_is_fixed_point():
    u = np.full((8, 16), 3.5, dtype=np.float32)
    got = np.asarray(heat.heat_strip_step(jnp.array(u), 0.25))
    np.testing.assert_allclose(got, u[1:-1], rtol=0, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    rows=st.integers(3, 40),
    w=st.integers(3, 80),
    alpha=st.floats(0.01, 0.25),
    seed=st.integers(0, 2**31 - 1),
)
def test_heat_strip_hypothesis(rows, w, alpha, seed):
    g = _rng(seed)
    u = g.standard_normal((rows, w)).astype(np.float32)
    got = heat.heat_strip_step(jnp.array(u), np.float32(alpha))
    want = ref.heat_strip_step(u, np.float32(alpha))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ------------------------------------------------- oracle self-consistency

def test_ref_jacobi_solve_converges():
    """Residual-correction Jacobi drives a diag-dominant system to x*."""
    n = 64
    g = _rng(42)
    a = g.standard_normal((n, n)).astype(np.float32) * 0.05
    a[np.arange(n), np.arange(n)] = 4.0
    x_star = g.standard_normal(n).astype(np.float32)
    b = a @ x_star
    x = np.asarray(ref.jacobi_solve(jnp.array(a), jnp.array(b), 200))
    np.testing.assert_allclose(x, x_star, rtol=1e-3, atol=1e-3)
